//! Coding-layer simulation with synthetic payload vectors.
//!
//! Runs the full CoGC communication round — gradient sharing, partial sums,
//! uplink erasure, standard GC decode, GC⁺ decode — on synthetic gradient
//! vectors, *without* the model runtime. This validates the decode maths
//! end-to-end (recovered payloads vs ground truth) and produces the
//! statistics of Figs. 4/6 quickly; the `coordinator` module runs the same
//! round structure against real model payloads.
//!
//! Entry points: [`simulate_round`] for one fully-inspectable round
//! ([`SimRound`] carries the aggregate, the ground truth, and the decode
//! error) and [`sweep`] for [`MonteCarlo`]-parallel trial sweeps folding
//! into [`SweepStats`]. All randomness flows through explicit `Rng`
//! streams, so sweeps are bit-identical at every `--threads` value.
//!
//! Link erasures are drawn through a (possibly stateful)
//! [`ChannelModel`](crate::scenario::ChannelModel): repeated attempts
//! within a round see the channel state *evolve* (a burst can kill
//! consecutive repeats — exactly the regime where repetition stops
//! helping), and [`sweep`] resets a fresh per-trial state from the
//! [`CHANNEL_STREAM`](crate::scenario::CHANNEL_STREAM) substream so tallies
//! stay bit-identical at any thread count. Pass
//! [`Iid`](crate::scenario::Iid) for the paper's memoryless behavior.

use crate::gc::{self, GcCode};
use crate::linalg::Matrix;
use crate::network::{Network, Realization};
use crate::parallel::{Accumulate, MonteCarlo};
use crate::scenario::{ChannelModel, CHANNEL_STREAM};
use crate::util::rng::Rng;

/// Outcome of one simulated round.
#[derive(Clone, Debug, PartialEq)]
pub enum Outcome {
    /// Standard GC decoded the exact sum (attempt index that succeeded).
    Standard { attempt: usize },
    /// GC⁺ recovered all M local payloads.
    Full,
    /// GC⁺ recovered a proper subset.
    Partial { k4: Vec<usize> },
    /// Nothing decodable.
    None,
}

#[derive(Clone, Debug)]
pub struct SimRound {
    pub outcome: Outcome,
    /// The PS-side aggregate: exact mean (standard / full) or subset mean
    /// (partial); `None` when the round decoded nothing.
    pub aggregate: Option<Vec<f64>>,
    /// Ground-truth mean over all M payloads.
    pub true_mean: Vec<f64>,
    /// Max |aggregate − achievable target| (exact mean for Standard/Full,
    /// subset mean for Partial) — the numerical decode error.
    pub decode_err: f64,
    pub transmissions: usize,
}

/// Decode policy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Decoder {
    /// Standard GC over `attempts` repeats; all-or-nothing per attempt.
    Standard { attempts: usize },
    /// GC⁺ over `tr` stacked attempts (complete + incomplete sums uplinked).
    GcPlus { tr: usize },
}

/// Reusable per-worker buffers of [`simulate_round_scratch`]: the channel
/// realization, the observed attempts, the delivered partial sums (in
/// stack order), and the persistent incremental GC⁺ decoder. One instance
/// per worker serves every trial of a sweep — steady-state rounds allocate
/// only their returned [`SimRound`].
pub struct SimScratch {
    real: Realization,
    payload: Matrix,
    /// Observed attempts of the round (slots reused across trials).
    attempts: Vec<gc::Attempt>,
    /// Partial sums of the delivered rows, stacked across attempts in the
    /// exact order the decoder rows were pushed.
    sums: Matrix,
    /// Start row of each attempt's block inside `sums`.
    starts: Vec<usize>,
    dec: gc::GcPlusDecoder,
}

impl SimScratch {
    pub fn new() -> SimScratch {
        SimScratch {
            real: Realization::perfect(0),
            payload: Matrix::zeros(0, 0),
            attempts: Vec::new(),
            sums: Matrix::zeros(0, 0),
            starts: Vec::new(),
            dec: gc::GcPlusDecoder::new(0),
        }
    }
}

impl Default for SimScratch {
    fn default() -> Self {
        SimScratch::new()
    }
}

/// Simulate one CoGC round over synthetic payloads `G` (`M×D` normal).
///
/// `ch` supplies the link realizations and must have been `reset` for this
/// trial (stateless models like `Iid` need no reset); its state evolves
/// across the round's communication attempts. Allocating convenience form
/// of [`simulate_round_scratch`].
pub fn simulate_round(
    net: &Network,
    ch: &mut dyn ChannelModel,
    m: usize,
    s: usize,
    d: usize,
    decoder: Decoder,
    rng: &mut Rng,
) -> SimRound {
    let mut scratch = SimScratch::new();
    simulate_round_scratch(net, ch, m, s, d, decoder, rng, &mut scratch)
}

/// [`simulate_round`] with pooled buffers: the GC⁺ path feeds each
/// attempt's delivered coefficient rows into the persistent incremental
/// decoder (no re-stack, no per-block re-RREF) and computes partial sums
/// only for delivered rows. Identical outcomes and draw order to the
/// allocating form for every `(net, seed)`.
#[allow(clippy::too_many_arguments)]
pub fn simulate_round_scratch(
    net: &Network,
    ch: &mut dyn ChannelModel,
    m: usize,
    s: usize,
    d: usize,
    decoder: Decoder,
    rng: &mut Rng,
    sc: &mut SimScratch,
) -> SimRound {
    // synthetic payloads, drawn in the canonical row-major order
    if sc.payload.rows != m || sc.payload.cols != d {
        sc.payload = Matrix::zeros(m, d);
    }
    for x in &mut sc.payload.data {
        *x = rng.normal();
    }
    let payload = &sc.payload;
    let true_mean: Vec<f64> = (0..d)
        .map(|j| (0..m).map(|i| payload[(i, j)]).sum::<f64>() / m as f64)
        .collect();

    let attempts_n = match decoder {
        Decoder::Standard { attempts } => attempts,
        Decoder::GcPlus { tr } => tr,
    };

    sc.dec.reset(m);
    if sc.sums.cols != d {
        sc.sums = Matrix::zeros(0, d);
    } else {
        sc.sums.clear_rows();
    }
    sc.starts.clear();
    let mut transmissions = 0usize;

    for a in 0..attempts_n {
        let code = GcCode::generate(m, s, rng);
        ch.sample_into(net, rng, &mut sc.real);
        if sc.attempts.len() <= a {
            sc.attempts.push(gc::Attempt::empty());
        }
        let att = &mut sc.attempts[a];
        gc::Attempt::observe_into(&code, &sc.real, att);
        // gradient-sharing phase: s transmissions per client
        transmissions += s * m;
        // uplink: standard GC sends only complete sums; GC+ sends all
        transmissions += match decoder {
            Decoder::Standard { .. } => att.complete.len(),
            Decoder::GcPlus { .. } => m, // every client attempts its uplink
        };
        // partial sums of the *delivered* rows only, pushed in stack order
        sc.starts.push(sc.sums.rows);
        for &r in &att.delivered {
            let start = sc.sums.data.len();
            sc.sums.data.resize(start + d, 0.0);
            sc.sums.rows += 1;
            let orow = &mut sc.sums.data[start..start + d];
            for k in 0..m {
                let c = att.perturbed[(r, k)];
                if c == 0.0 {
                    continue;
                }
                for (o, p) in orow.iter_mut().zip(payload.row(k)) {
                    *o += c * p;
                }
            }
            if matches!(decoder, Decoder::GcPlus { .. }) {
                sc.dec.push_row(att.perturbed.row(r));
            }
        }
    }

    // 1) standard decode on any single attempt with >= M - s complete sums
    for (i, att) in sc.attempts[..attempts_n].iter().enumerate() {
        if att.complete.len() < m - s {
            continue;
        }
        // complete rows of the perturbed matrix are exactly the original
        // code rows, so the combinator solve runs on them directly
        let Some(a) = gc::combinator::find_combinator_rows(&att.perturbed, s, &att.complete)
        else {
            continue;
        };
        // combine the delivered partial sums (combinator support is on
        // complete ⊆ delivered rows, in ascending order as before)
        let mut got = vec![0.0f64; d];
        for (off, &r) in att.delivered.iter().enumerate() {
            let coef = a[r];
            if coef == 0.0 {
                continue;
            }
            for (o, v) in got.iter_mut().zip(sc.sums.row(sc.starts[i] + off)) {
                *o += coef * v;
            }
        }
        let target: Vec<f64> = true_mean.iter().map(|x| x * m as f64).collect();
        let err = max_abs_diff(&got, &target);
        let aggregate: Vec<f64> = got.iter().map(|x| x / m as f64).collect();
        return SimRound {
            outcome: Outcome::Standard { attempt: i },
            aggregate: Some(aggregate),
            true_mean,
            decode_err: err,
            transmissions,
        };
    }

    if let Decoder::Standard { .. } = decoder {
        return SimRound {
            outcome: Outcome::None,
            aggregate: None,
            true_mean,
            decode_err: 0.0,
            transmissions,
        };
    }

    // 2) GC+ complementary decode: the incremental engine already holds
    // the reduced form of every delivered coefficient row
    if sc.dec.decodable_count() == 0 {
        return SimRound {
            outcome: Outcome::None,
            aggregate: None,
            true_mean,
            decode_err: 0.0,
            transmissions,
        };
    }
    let dec = sc.dec.decode();
    let decoded = dec.weights.matmul(&sc.sums);
    // decode error vs the true individual payloads
    let mut err = 0.0f64;
    for (i, &client) in dec.k4.iter().enumerate() {
        err = err.max(max_abs_diff(decoded.row(i), payload.row(client)));
    }
    // aggregate = mean over K4 (paper eq. (23))
    let aggregate: Vec<f64> = (0..d)
        .map(|j| (0..dec.k4.len()).map(|i| decoded[(i, j)]).sum::<f64>() / dec.k4.len() as f64)
        .collect();
    let outcome = if dec.k4.len() == m {
        Outcome::Full
    } else {
        Outcome::Partial { k4: dec.k4 }
    };
    SimRound { outcome, aggregate: Some(aggregate), true_mean, decode_err: err, transmissions }
}

fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

/// Aggregate tallies of a [`sweep`] over many simulated rounds.
///
/// Every field combines associatively (counts, integer sums, a maximum), so
/// per-worker instances merge exactly — the requirement of the parallel
/// engine's determinism guarantee. Note the decode error is tracked as a
/// *maximum* (order-independent), never an order-sensitive float sum.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SweepStats {
    pub trials: usize,
    /// Rounds decoded by the standard (binary) GC combinator.
    pub standard: usize,
    /// Rounds where GC⁺ recovered all M payloads.
    pub full: usize,
    /// Rounds where GC⁺ recovered a proper subset.
    pub partial: usize,
    /// Rounds with nothing decodable.
    pub none: usize,
    /// Total transmissions consumed across all rounds.
    pub transmissions: usize,
    /// Worst numerical decode error observed over all decoding rounds.
    pub max_decode_err: f64,
}

impl SweepStats {
    /// Fraction of rounds that produced *some* global update.
    pub fn p_update(&self) -> f64 {
        (self.standard + self.full + self.partial) as f64 / self.trials as f64
    }

    pub fn mean_transmissions(&self) -> f64 {
        self.transmissions as f64 / self.trials as f64
    }
}

impl Accumulate for SweepStats {
    fn merge(&mut self, other: Self) {
        self.trials += other.trials;
        self.standard += other.standard;
        self.full += other.full;
        self.partial += other.partial;
        self.none += other.none;
        self.transmissions += other.transmissions;
        self.max_decode_err = self.max_decode_err.max(other.max_decode_err);
    }
}

/// Run `trials` independent [`simulate_round`]s through the parallel engine
/// and tally the outcomes. Bit-identical for any thread count.
///
/// `ch` is a prototype: the engine clones it once per worker and resets the
/// clone from each trial's channel-state substream, so stateful models are
/// independent across trials and identical for every work-stealing
/// schedule. All round buffers (realization, attempts, partial sums, the
/// incremental decoder) are pooled per worker via [`SimScratch`] — the
/// steady-state trial body allocates only its round result.
pub fn sweep(
    net: &Network,
    ch: &dyn ChannelModel,
    m: usize,
    s: usize,
    d: usize,
    decoder: Decoder,
    trials: usize,
    mc: &MonteCarlo,
) -> SweepStats {
    mc.run_scratch(
        trials,
        || (ch.clone_box(), SimScratch::new()),
        |t, rng, acc: &mut SweepStats, (chb, sc)| {
            chb.reset(net, mc.substream_seed(CHANNEL_STREAM, t));
            let r = simulate_round_scratch(net, &mut **chb, m, s, d, decoder, rng, sc);
            acc.trials += 1;
            match r.outcome {
                Outcome::Standard { .. } => acc.standard += 1,
                Outcome::Full => acc.full += 1,
                Outcome::Partial { .. } => acc.partial += 1,
                Outcome::None => acc.none += 1,
            }
            acc.transmissions += r.transmissions;
            acc.max_decode_err = acc.max_decode_err.max(r.decode_err);
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Iid;
    use crate::testing::Prop;

    #[test]
    fn perfect_network_standard_decodes_exactly() {
        let net = Network::perfect(10);
        let mut rng = Rng::new(1);
        let r =
            simulate_round(&net, &mut Iid, 10, 7, 23, Decoder::Standard { attempts: 1 }, &mut rng);
        assert!(matches!(r.outcome, Outcome::Standard { attempt: 0 }));
        assert!(r.decode_err < 1e-6, "err = {}", r.decode_err);
        let agg = r.aggregate.unwrap();
        assert!(max_abs_diff(&agg, &r.true_mean) < 1e-9);
        // transmissions: sM + M complete uplinks = 7*10 + 10
        assert_eq!(r.transmissions, 80);
    }

    #[test]
    fn gcplus_full_recovery_matches_true_mean() {
        // moderate c2c erasures + good uplinks: standard GC often fails
        // (incomplete sums) but the perturbation-boosted rank lets GC+
        // achieve full recovery, matching the exact mean.
        let net = Network::homogeneous(10, 0.1, 0.5);
        let mut rng = Rng::new(2);
        let mut fulls = 0;
        for _ in 0..60 {
            let r =
                simulate_round(&net, &mut Iid, 10, 7, 11, Decoder::GcPlus { tr: 2 }, &mut rng);
            if r.outcome == Outcome::Full {
                fulls += 1;
                assert!(r.decode_err < 1e-6);
                assert!(max_abs_diff(&r.aggregate.unwrap(), &r.true_mean) < 1e-8);
            }
        }
        assert!(fulls > 10, "full recoveries: {fulls}");
    }

    #[test]
    fn prop_decode_error_always_small_when_decoding() {
        Prop::new(30).forall("sim decode error", |rng, _| {
            let m = rng.range(4, 11);
            let s = rng.range(1, m);
            let p = rng.uniform(0.1, 0.8);
            let net = Network::homogeneous(m, p, p);
            let dec = if rng.bernoulli(0.5) {
                Decoder::Standard { attempts: 2 }
            } else {
                Decoder::GcPlus { tr: 2 }
            };
            let r = simulate_round(&net, &mut Iid, m, s, 9, dec, rng);
            assert!(
                r.decode_err < 1e-5,
                "decode error {} (outcome {:?})",
                r.decode_err,
                r.outcome
            );
        });
    }

    #[test]
    fn sweep_tallies_partition_and_decode_exactly() {
        let net = Network::homogeneous(8, 0.3, 0.3);
        let st = sweep(&net, &Iid, 8, 3, 5, Decoder::GcPlus { tr: 2 }, 300, &MonteCarlo::new(9));
        assert_eq!(st.trials, 300);
        assert_eq!(st.standard + st.full + st.partial + st.none, st.trials);
        assert!(st.p_update() > 0.0 && st.p_update() <= 1.0);
        assert!(st.mean_transmissions() > 0.0);
        assert!(st.max_decode_err < 1e-5, "decode err {}", st.max_decode_err);
    }

    #[test]
    fn sweep_is_thread_count_invariant() {
        let net = Network::homogeneous(8, 0.4, 0.4);
        let run = |threads: usize| {
            sweep(
                &net,
                &Iid,
                8,
                3,
                5,
                Decoder::GcPlus { tr: 2 },
                400,
                &MonteCarlo::new(17).with_threads(threads),
            )
        };
        let want = run(1);
        for threads in [2usize, 8] {
            assert_eq!(run(threads), want, "threads={threads}");
        }
    }

    #[test]
    fn standard_none_when_all_uplinks_dead() {
        let net = Network::homogeneous(6, 1.0, 0.0);
        let mut rng = Rng::new(3);
        let r =
            simulate_round(&net, &mut Iid, 6, 2, 5, Decoder::Standard { attempts: 3 }, &mut rng);
        assert_eq!(r.outcome, Outcome::None);
        assert!(r.aggregate.is_none());
    }
}
