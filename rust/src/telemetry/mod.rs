//! Deterministic telemetry: sharded hot-path counters, phase timing, and
//! decode/channel introspection across the whole simulator.
//!
//! Design contract (mirrors the parallel engine's determinism scheme):
//!
//! - **Hot path = plain integer bumps on a per-worker [`Shard`]** pooled
//!   inside the existing scratch structs (`TrialScratch`, `SimScratch`,
//!   worker scratch factories) — no atomics, no locks, no allocations,
//!   armed or disarmed (`tests/telemetry_alloc.rs` pins this).
//! - **Deterministic section**: shards hold only counters, max-gauges and
//!   fixed-bucket log₂ histograms. Every merge is a commutative integer
//!   operation and the engine merges worker shards in worker-index order
//!   ([`crate::parallel::MonteCarlo::run_scratch_tel`]), so the merged
//!   registry values are bit-identical at any `--threads` even though the
//!   chunk→worker assignment is racy.
//! - **Non-deterministic section**: wall-clock phase scopes ([`phase`])
//!   and per-worker throughput ([`record_worker`]) are recorded only when
//!   the registry is [`armed`] and are exported under a separate,
//!   clearly-marked `non_deterministic` JSON key, so the CSV/JSON
//!   byte-equality guarantees of the determinism tests survive arming.
//!
//! Export: [`export_json`] backs `--telemetry <out.json>` on `scenario
//! run`, `train`, and the figure subcommands; [`summary_table`] renders a
//! human-readable end-of-run table through [`crate::metrics::Table`]; and
//! [`render_prometheus`] is the text-format seam for the future
//! `cogc serve` scrape endpoint (ROADMAP). [`check_json`] is the
//! dependency-free sanity check behind `cogc telemetry check`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::json::{self, Json};

/// Metric identifiers: a fixed layout so a [`Shard`] is a handful of flat
/// arrays and `inc`/`add`/`observe` are plain index bumps on the hot path.
pub mod metric {
    // -- counters ---------------------------------------------------------
    /// Float coefficient rows pushed into `GcPlusDecoder`.
    pub const DEC_ROWS_PUSHED: usize = 0;
    /// Rows resolved by the degree-one peeling fast path.
    pub const DEC_ROWS_PEELED: usize = 1;
    /// Rows forwarded past peeling into the dense elimination.
    pub const DEC_ROWS_FORWARDED: usize = 2;
    /// Integer rows pushed into the exact `IntRref` engine (binary family).
    pub const DEC_INT_ROWS_PUSHED: usize = 3;
    /// Decode episodes harvested (one per simulated round / trial block).
    pub const DEC_EPISODES: usize = 4;
    /// Byzantine parity-audit invocations.
    pub const AUDIT_CHECKS: usize = 5;
    /// Rows excised by the Byzantine audit.
    pub const AUDIT_EXCISIONS: usize = 6;
    /// Channel link samples drawn (dense entries or sparse support slots).
    pub const CH_SAMPLES: usize = 7;
    /// Samples drawn while the sampled chain was in a degraded state.
    pub const CH_DEGRADED: usize = 8;
    /// Denominator for state occupancy (chain steps observed).
    pub const CH_DEGRADED_DENOM: usize = 9;
    /// Degraded→healthy chain transitions (burst/fade/straggle spells
    /// ended); mean dwell = `ch_degraded / ch_burst_ends`.
    pub const CH_BURST_ENDS: usize = 10;
    /// Deadline-straggler deliveries that met the round deadline.
    pub const CH_DEADLINE_HITS: usize = 11;
    /// Deadline-straggler deliveries attempted.
    pub const CH_DEADLINE_TOTAL: usize = 12;
    /// Monte-Carlo trials executed through the engine.
    pub const MC_TRIALS: usize = 13;
    /// Monte-Carlo chunks drained from the work queue.
    pub const MC_CHUNKS: usize = 14;
    /// Items mapped through `parallel_map`.
    pub const PM_ITEMS: usize = 15;
    /// Degraded-mode rounds accepted via the least-squares fallback.
    pub const APPROX_FALLBACKS: usize = 16;
    /// Link retransmissions attempted by recovery policies.
    pub const POLICY_RETRIES: usize = 17;
    /// Number of counters; `COUNTER_NAMES` must match.
    pub const COUNTERS: usize = 18;
    pub const COUNTER_NAMES: [&str; COUNTERS] = [
        "dec_rows_pushed",
        "dec_rows_peeled",
        "dec_rows_forwarded",
        "dec_int_rows_pushed",
        "dec_episodes",
        "audit_checks",
        "audit_excisions",
        "ch_samples",
        "ch_degraded",
        "ch_degraded_denom",
        "ch_burst_ends",
        "ch_deadline_hits",
        "ch_deadline_total",
        "mc_trials",
        "mc_chunks",
        "pm_items",
        "approx_fallbacks",
        "policy_retries",
    ];

    // -- max-gauges -------------------------------------------------------
    /// Highest stacked-matrix rank seen in any decode episode.
    pub const DEC_MAX_RANK: usize = 0;
    /// Most coefficient rows stacked in any decode episode.
    pub const DEC_MAX_ROWS: usize = 1;
    pub const GAUGES: usize = 2;
    pub const GAUGE_NAMES: [&str; GAUGES] = ["dec_max_rank", "dec_max_rows"];

    // -- log₂ histograms --------------------------------------------------
    /// Final rank per decode episode.
    pub const H_DEC_RANK: usize = 0;
    /// Rows pushed per decode episode.
    pub const H_DEC_ROWS: usize = 1;
    /// Rows peeled per decode episode.
    pub const H_DEC_PEELED: usize = 2;
    pub const HISTS: usize = 3;
    pub const HIST_NAMES: [&str; HISTS] = ["dec_rank", "dec_rows", "dec_peeled"];
    /// Bucket `0` holds exactly the value 0; bucket `k ≥ 1` holds values in
    /// `[2^(k-1), 2^k)`; the last bucket absorbs everything larger.
    pub const HIST_BUCKETS: usize = 16;
}

/// log₂ bucket index for a histogram observation (see [`metric::HIST_BUCKETS`]).
#[inline]
pub fn bucket(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        ((64 - v.leading_zeros()) as usize).min(metric::HIST_BUCKETS - 1)
    }
}

/// One worker's private metric arrays: the only thing trial bodies touch.
///
/// All fields are fixed-size integer arrays, so `clone` is a memcpy
/// (no heap), `merge` is element-wise add/max (commutative — the basis of
/// the thread-count invariance), and every recording method is a plain
/// index bump. Pool one of these per worker scratch; the engine snapshots
/// and merges them in worker-index order after the join.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Shard {
    counters: [u64; metric::COUNTERS],
    gauges: [u64; metric::GAUGES],
    hist: [[u64; metric::HIST_BUCKETS]; metric::HISTS],
    hist_sum: [u64; metric::HISTS],
}

impl Shard {
    pub const fn new() -> Shard {
        Shard {
            counters: [0; metric::COUNTERS],
            gauges: [0; metric::GAUGES],
            hist: [[0; metric::HIST_BUCKETS]; metric::HISTS],
            hist_sum: [0; metric::HISTS],
        }
    }

    /// Zero every metric, keeping the (stack-only) storage.
    pub fn clear(&mut self) {
        *self = Shard::new();
    }

    #[inline]
    pub fn inc(&mut self, c: usize) {
        self.counters[c] += 1;
    }

    #[inline]
    pub fn add(&mut self, c: usize, n: u64) {
        self.counters[c] += n;
    }

    #[inline]
    pub fn gauge_max(&mut self, g: usize, v: u64) {
        if v > self.gauges[g] {
            self.gauges[g] = v;
        }
    }

    #[inline]
    pub fn observe(&mut self, h: usize, v: u64) {
        self.hist[h][bucket(v)] += 1;
        self.hist_sum[h] += v;
    }

    pub fn counter(&self, c: usize) -> u64 {
        self.counters[c]
    }

    pub fn gauge(&self, g: usize) -> u64 {
        self.gauges[g]
    }

    /// Observations recorded into histogram `h`.
    pub fn hist_count(&self, h: usize) -> u64 {
        self.hist[h].iter().sum()
    }

    /// Element-wise merge: counter/histogram adds, gauge maxes. Commutative
    /// and associative, so any merge order yields identical values.
    pub fn merge(&mut self, other: &Shard) {
        for (a, b) in self.counters.iter_mut().zip(other.counters.iter()) {
            *a += b;
        }
        for (a, b) in self.gauges.iter_mut().zip(other.gauges.iter()) {
            *a = (*a).max(*b);
        }
        for (ha, hb) in self.hist.iter_mut().zip(other.hist.iter()) {
            for (a, b) in ha.iter_mut().zip(hb.iter()) {
                *a += b;
            }
        }
        for (a, b) in self.hist_sum.iter_mut().zip(other.hist_sum.iter()) {
            *a += b;
        }
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self == &Shard::new()
    }

    /// Fold one round's channel diagnostics into the channel counters.
    pub fn absorb_channel(&mut self, st: &crate::scenario::ChannelStats) {
        self.add(metric::CH_SAMPLES, st.samples as u64);
        self.add(metric::CH_DEGRADED, st.degraded as u64);
        self.add(metric::CH_DEGRADED_DENOM, st.degraded_denom as u64);
        self.add(metric::CH_BURST_ENDS, st.burst_ends as u64);
        self.add(metric::CH_DEADLINE_HITS, st.deadline_hits as u64);
        self.add(metric::CH_DEADLINE_TOTAL, st.deadline_total as u64);
    }

    /// Fold one exact-integer decode episode ([`IntRref`]-based paths)
    /// into the shard: `rows` pushed rows, `rank` the final rank.
    ///
    /// [`IntRref`]: crate::gc::IntRref
    pub fn absorb_int_engine(&mut self, rows: u64, rank: u64) {
        self.inc(metric::DEC_EPISODES);
        self.add(metric::DEC_INT_ROWS_PUSHED, rows);
        self.observe(metric::H_DEC_ROWS, rows);
        self.observe(metric::H_DEC_RANK, rank);
        self.gauge_max(metric::DEC_MAX_RANK, rank);
        self.gauge_max(metric::DEC_MAX_ROWS, rows);
    }
}

impl Default for Shard {
    fn default() -> Shard {
        Shard::new()
    }
}

/// Shard projection for scratch types that carry no shard — the plain
/// [`run_scratch`](crate::parallel::MonteCarlo::run_scratch) path.
pub fn no_shard<S>(_: &mut S) -> Option<&mut Shard> {
    None
}

// ---------------------------------------------------------------------------
// Global registry
// ---------------------------------------------------------------------------

#[derive(Clone, Debug, Default)]
struct PhaseStat {
    count: u64,
    total_ns: u64,
    max_ns: u64,
}

#[derive(Clone, Debug)]
struct WorkerStat {
    pool: &'static str,
    worker: usize,
    items: u64,
    elapsed_ns: u64,
}

struct Inner {
    shard: Shard,
    phases: BTreeMap<&'static str, PhaseStat>,
    workers: Vec<WorkerStat>,
}

/// Whether wall-clock capture + export are requested (`--telemetry`).
static ARMED: AtomicBool = AtomicBool::new(false);
static INNER: Mutex<Inner> =
    Mutex::new(Inner { shard: Shard::new(), phases: BTreeMap::new(), workers: Vec::new() });

#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

pub fn arm() {
    ARMED.store(true, Ordering::Relaxed);
}

pub fn disarm() {
    ARMED.store(false, Ordering::Relaxed);
}

/// Clear every registered value (tests and multi-run CLI sessions).
pub fn reset() {
    let mut inner = INNER.lock().unwrap();
    inner.shard.clear();
    inner.phases.clear();
    inner.workers.clear();
}

/// Merge a worker shard into the registry. The engine calls this in
/// worker-index order after the join; the serial path calls it once.
pub fn merge_shard(shard: &Shard) {
    if shard.is_empty() {
        return;
    }
    INNER.lock().unwrap().shard.merge(shard);
}

/// Bump a registry counter directly (for engine-level deterministic counts
/// that have no scratch shard, e.g. `parallel_map` item totals). Armed
/// only: callers sit outside per-trial bodies but may still be per-round.
pub fn count(c: usize, n: u64) {
    if armed() && n > 0 {
        INNER.lock().unwrap().shard.add(c, n);
    }
}

/// Record one worker's throughput (non-deterministic section; armed only).
pub fn record_worker(pool: &'static str, worker: usize, items: u64, elapsed: Duration) {
    if !armed() {
        return;
    }
    INNER.lock().unwrap().workers.push(WorkerStat {
        pool,
        worker,
        items,
        elapsed_ns: elapsed.as_nanos() as u64,
    });
}

/// Record one completed phase scope (non-deterministic section).
pub fn record_phase(name: &'static str, elapsed: Duration) {
    let ns = elapsed.as_nanos() as u64;
    let mut inner = INNER.lock().unwrap();
    let st = inner.phases.entry(name).or_default();
    st.count += 1;
    st.total_ns += ns;
    st.max_ns = st.max_ns.max(ns);
}

/// RAII wall-clock scope. Disarmed it is a no-op shell: no clock read, no
/// lock, no allocation — safe to drop into hot-ish paths.
pub struct PhaseGuard {
    name: &'static str,
    start: Option<Instant>,
}

/// Open a named phase scope; elapsed time is recorded on drop when armed.
pub fn phase(name: &'static str) -> PhaseGuard {
    PhaseGuard { name, start: if armed() { Some(Instant::now()) } else { None } }
}

impl Drop for PhaseGuard {
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            record_phase(self.name, t0.elapsed());
        }
    }
}

/// Snapshot of the merged deterministic section (tests assert equality
/// across `--threads`; the export paths render from it).
pub fn snapshot() -> Shard {
    INNER.lock().unwrap().shard.clone()
}

/// Serializes registry-touching unit tests across modules: the registry is
/// process-global and cargo runs test fns on parallel threads.
#[cfg(test)]
pub(crate) static TEST_LOCK: Mutex<()> = Mutex::new(());

// ---------------------------------------------------------------------------
// Export: JSON / Prometheus text / summary table
// ---------------------------------------------------------------------------

const SCHEMA_VERSION: f64 = 1.0;
const NONDET_NOTE: &str =
    "wall-clock values; vary run to run and are excluded from determinism guarantees";

/// Render the full registry. Deterministic metrics and wall-clock values
/// live under separate top-level keys; serialization order is fixed
/// (BTreeMap), so the `deterministic` subtree is byte-stable across runs.
pub fn export_json() -> Json {
    let inner = INNER.lock().unwrap();
    let sh = &inner.shard;
    let counters = Json::Obj(
        metric::COUNTER_NAMES
            .iter()
            .zip(sh.counters.iter())
            .map(|(n, v)| (n.to_string(), json::num(*v as f64)))
            .collect(),
    );
    let gauges = Json::Obj(
        metric::GAUGE_NAMES
            .iter()
            .zip(sh.gauges.iter())
            .map(|(n, v)| (n.to_string(), json::num(*v as f64)))
            .collect(),
    );
    let hists = Json::Obj(
        metric::HIST_NAMES
            .iter()
            .enumerate()
            .map(|(h, n)| {
                let buckets = Json::Arr(sh.hist[h].iter().map(|&b| json::num(b as f64)).collect());
                (
                    n.to_string(),
                    json::obj(vec![
                        ("buckets", buckets),
                        ("count", json::num(sh.hist_count(h) as f64)),
                        ("sum", json::num(sh.hist_sum[h] as f64)),
                    ]),
                )
            })
            .collect(),
    );
    let phases = Json::Obj(
        inner
            .phases
            .iter()
            .map(|(name, st)| {
                (
                    name.to_string(),
                    json::obj(vec![
                        ("count", json::num(st.count as f64)),
                        ("total_s", json::num(st.total_ns as f64 * 1e-9)),
                        ("max_s", json::num(st.max_ns as f64 * 1e-9)),
                    ]),
                )
            })
            .collect(),
    );
    let workers = Json::Arr(
        inner
            .workers
            .iter()
            .map(|w| {
                json::obj(vec![
                    ("pool", json::s(w.pool)),
                    ("worker", json::num(w.worker as f64)),
                    ("items", json::num(w.items as f64)),
                    ("elapsed_s", json::num(w.elapsed_ns as f64 * 1e-9)),
                ])
            })
            .collect(),
    );
    json::obj(vec![
        ("version", json::num(SCHEMA_VERSION)),
        (
            "deterministic",
            json::obj(vec![("counters", counters), ("gauges", gauges), ("histograms", hists)]),
        ),
        (
            "non_deterministic",
            json::obj(vec![
                ("note", json::s(NONDET_NOTE)),
                ("phases", phases),
                ("workers", workers),
            ]),
        ),
    ])
}

/// Write [`export_json`] to `path` with a trailing newline.
pub fn write_json(path: &std::path::Path) -> std::io::Result<()> {
    let mut text = export_json().serialize();
    text.push('\n');
    std::fs::write(path, text)
}

/// Prometheus text exposition of the registry — the scrape-format seam for
/// the future `cogc serve` endpoint. Counter/gauge names are prefixed
/// `cogc_`; histograms render cumulative `_bucket{le=...}` series with
/// power-of-two upper bounds; phase wall-clock renders as labelled
/// counters in seconds.
pub fn render_prometheus() -> String {
    use std::fmt::Write as _;
    let inner = INNER.lock().unwrap();
    let sh = &inner.shard;
    let mut out = String::new();
    for (n, v) in metric::COUNTER_NAMES.iter().zip(sh.counters.iter()) {
        let _ = writeln!(out, "# TYPE cogc_{n} counter\ncogc_{n} {v}");
    }
    for (n, v) in metric::GAUGE_NAMES.iter().zip(sh.gauges.iter()) {
        let _ = writeln!(out, "# TYPE cogc_{n} gauge\ncogc_{n} {v}");
    }
    for (h, n) in metric::HIST_NAMES.iter().enumerate() {
        let _ = writeln!(out, "# TYPE cogc_{n} histogram");
        let mut cum = 0u64;
        for (k, b) in sh.hist[h].iter().enumerate() {
            cum += b;
            // bucket k ≥ 1 holds [2^(k-1), 2^k): inclusive upper bound 2^k - 1
            let le = if k == 0 { 0 } else { (1u64 << k) - 1 };
            let _ = writeln!(out, "cogc_{n}_bucket{{le=\"{le}\"}} {cum}");
        }
        let _ = writeln!(out, "cogc_{n}_bucket{{le=\"+Inf\"}} {cum}");
        let _ = writeln!(out, "cogc_{n}_sum {}\ncogc_{n}_count {cum}", sh.hist_sum[h]);
    }
    let _ = writeln!(out, "# TYPE cogc_phase_seconds_total counter");
    for (name, st) in inner.phases.iter() {
        let _ = writeln!(
            out,
            "cogc_phase_seconds_total{{phase=\"{name}\"}} {:.9}",
            st.total_ns as f64 * 1e-9
        );
    }
    out
}

/// Human-readable end-of-run summary (nonzero metrics + phase timings),
/// rendered through the shared CSV table type.
pub fn summary_table() -> crate::metrics::Table {
    let inner = INNER.lock().unwrap();
    let sh = &inner.shard;
    let mut t = crate::metrics::Table::new(
        "telemetry summary: deterministic counters/gauges, then wall-clock phases",
        &["metric", "value"],
    );
    for (n, v) in metric::COUNTER_NAMES.iter().zip(sh.counters.iter()) {
        if *v > 0 {
            t.row(&[n.to_string(), v.to_string()]);
        }
    }
    for (n, v) in metric::GAUGE_NAMES.iter().zip(sh.gauges.iter()) {
        if *v > 0 {
            t.row(&[n.to_string(), v.to_string()]);
        }
    }
    for (h, n) in metric::HIST_NAMES.iter().enumerate() {
        let cnt = sh.hist_count(h);
        if cnt > 0 {
            t.row(&[format!("{n}_count"), cnt.to_string()]);
            t.row(&[format!("{n}_mean"), format!("{:.3}", sh.hist_sum[h] as f64 / cnt as f64)]);
        }
    }
    for (name, st) in inner.phases.iter() {
        t.row(&[format!("phase/{name}/count"), st.count.to_string()]);
        t.row(&[format!("phase/{name}/total_s"), format!("{:.6}", st.total_ns as f64 * 1e-9)]);
    }
    for w in inner.workers.iter() {
        t.row(&[
            format!("worker/{}/{}/items", w.pool, w.worker),
            format!("{} in {:.6}s", w.items, w.elapsed_ns as f64 * 1e-9),
        ]);
    }
    t
}

/// Validate an exported telemetry JSON file (the `cogc telemetry check`
/// subcommand — a jq-free CI sanity gate). Returns a one-line summary on
/// success, a diagnostic on failure.
pub fn check_json(text: &str) -> Result<String, String> {
    let v = Json::parse(text).map_err(|e| e.to_string())?;
    let version =
        v.get("version").and_then(Json::as_f64).ok_or("missing numeric \"version\"")?;
    if version != SCHEMA_VERSION {
        return Err(format!("unsupported telemetry schema version {version}"));
    }
    let det = v.get("deterministic").ok_or("missing \"deterministic\" section")?;
    let counters = det
        .get("counters")
        .and_then(Json::as_obj)
        .ok_or("missing \"deterministic.counters\" object")?;
    if counters.len() != metric::COUNTERS {
        return Err(format!(
            "expected {} counters, found {}",
            metric::COUNTERS,
            counters.len()
        ));
    }
    for (k, val) in counters {
        let x = val.as_f64().ok_or_else(|| format!("counter {k:?} is not a number"))?;
        if x < 0.0 || x.fract() != 0.0 {
            return Err(format!("counter {k:?} is not a non-negative integer: {x}"));
        }
    }
    let hists = det
        .get("histograms")
        .and_then(Json::as_obj)
        .ok_or("missing \"deterministic.histograms\" object")?;
    for (k, hv) in hists {
        let buckets = hv
            .get("buckets")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("histogram {k:?} has no bucket array"))?;
        if buckets.len() != metric::HIST_BUCKETS {
            return Err(format!("histogram {k:?} has {} buckets", buckets.len()));
        }
        let total: f64 = buckets.iter().filter_map(Json::as_f64).sum();
        let count = hv.get("count").and_then(Json::as_f64).unwrap_or(-1.0);
        if total != count {
            return Err(format!("histogram {k:?} count {count} != bucket sum {total}"));
        }
    }
    let nondet = v.get("non_deterministic").ok_or("missing \"non_deterministic\" section")?;
    let phases = nondet
        .get("phases")
        .and_then(Json::as_obj)
        .ok_or("missing \"non_deterministic.phases\" object")?;
    Ok(format!(
        "telemetry ok: {} counters, {} histograms, {} phases, {} worker rows",
        counters.len(),
        hists.len(),
        phases.len(),
        nondet.get("workers").and_then(Json::as_arr).map(<[Json]>::len).unwrap_or(0)
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_shard(rng: &mut Rng) -> Shard {
        let mut sh = Shard::new();
        for c in 0..metric::COUNTERS {
            sh.add(c, rng.range(0, 100) as u64);
        }
        for g in 0..metric::GAUGES {
            sh.gauge_max(g, rng.range(0, 1000) as u64);
        }
        for h in 0..metric::HISTS {
            for _ in 0..rng.range(0, 20) {
                sh.observe(h, rng.range(0, 100_000) as u64);
            }
        }
        sh
    }

    #[test]
    fn bucket_edges() {
        assert_eq!(bucket(0), 0);
        assert_eq!(bucket(1), 1);
        assert_eq!(bucket(2), 2);
        assert_eq!(bucket(3), 2);
        assert_eq!(bucket(4), 3);
        assert_eq!(bucket((1 << 14) - 1), 14);
        assert_eq!(bucket(1 << 14), 15);
        assert_eq!(bucket(u64::MAX), 15);
    }

    #[test]
    fn merge_is_order_independent() {
        let mut rng = Rng::new(0x7e1e_0007);
        for _ in 0..20 {
            let shards: Vec<Shard> = (0..6).map(|_| random_shard(&mut rng)).collect();
            let mut fwd = Shard::new();
            for s in &shards {
                fwd.merge(s);
            }
            let mut rev = Shard::new();
            for s in shards.iter().rev() {
                rev.merge(s);
            }
            let mut rot = Shard::new();
            for i in 0..shards.len() {
                rot.merge(&shards[(i + 3) % shards.len()]);
            }
            assert_eq!(fwd, rev, "forward vs reverse merge differ");
            assert_eq!(fwd, rot, "forward vs rotated merge differ");
        }
    }

    #[test]
    fn phase_guard_respects_armed_flag() {
        let _lock = TEST_LOCK.lock().unwrap();
        disarm();
        reset();
        {
            let _g = phase("test/disarmed");
        }
        assert!(export_json()
            .get("non_deterministic")
            .unwrap()
            .get("phases")
            .unwrap()
            .as_obj()
            .unwrap()
            .is_empty());
        arm();
        {
            let _g = phase("test/armed");
        }
        disarm();
        let j = export_json();
        let phases = j.get("non_deterministic").unwrap().get("phases").unwrap();
        assert_eq!(
            phases.get("test/armed").unwrap().get("count").unwrap().as_usize(),
            Some(1)
        );
        reset();
    }

    #[test]
    fn export_roundtrips_and_checks() {
        let _lock = TEST_LOCK.lock().unwrap();
        disarm();
        reset();
        let mut rng = Rng::new(11);
        merge_shard(&random_shard(&mut rng));
        let text = export_json().serialize();
        let msg = check_json(&text).expect("fresh export must validate");
        assert!(msg.starts_with("telemetry ok"));
        // parse → serialize is stable (BTreeMap ordering)
        assert_eq!(Json::parse(&text).unwrap().serialize(), text);
        let prom = render_prometheus();
        assert!(prom.contains("# TYPE cogc_dec_rows_pushed counter"));
        assert!(prom.contains("cogc_dec_rank_bucket{le=\"+Inf\"}"));
        let table = summary_table().to_csv();
        assert!(table.contains("metric,value"));
        reset();
    }

    #[test]
    fn check_rejects_malformed() {
        assert!(check_json("{").is_err());
        assert!(check_json("{\"version\": 9}").is_err());
        assert!(check_json("{\"version\": 1}").is_err());
    }

    #[test]
    fn shard_merge_into_registry_is_visible() {
        let _lock = TEST_LOCK.lock().unwrap();
        disarm();
        reset();
        let mut sh = Shard::new();
        sh.add(metric::DEC_ROWS_PUSHED, 5);
        sh.observe(metric::H_DEC_RANK, 7);
        merge_shard(&sh);
        merge_shard(&sh);
        let snap = snapshot();
        assert_eq!(snap.counter(metric::DEC_ROWS_PUSHED), 10);
        assert_eq!(snap.hist_count(metric::H_DEC_RANK), 2);
        reset();
        assert!(snapshot().is_empty());
    }
}
