//! Mini property-testing framework (substrate — proptest is not available
//! offline). Deterministic: every property runs `cases` seeds derived from a
//! base seed; failures report the failing case seed so they can be replayed
//! with [`forall_seeded`].
//!
//! Usage: `Prop::default().forall("name", |rng, case| { ... })` draws all
//! case randomness from `rng`; [`assert_close`] / [`assert_allclose`]
//! compare floats with relative-ish tolerance. `COGC_PROP_CASES` scales
//! the sweep size (CI keeps it small, local runs can crank it up), so
//! property tests stay fast without losing replayability.

use crate::runtime::{Batch, InputKind, ModelSpec};
use crate::util::rng::Rng;

/// Fixed-shape random batch for a model spec — shared by the model-step
/// benches and the runtime integration tests so the spec → batch mapping
/// lives in exactly one place.
pub fn fake_batch(spec: &ModelSpec, rng: &mut Rng) -> Batch {
    match spec.kind {
        InputKind::Image => Batch::Image {
            x: (0..spec.x_elems()).map(|_| rng.normal() as f32).collect(),
            y: (0..spec.y_elems()).map(|_| rng.below(spec.num_classes) as i32).collect(),
        },
        InputKind::Tokens => Batch::Tokens {
            x: (0..spec.x_elems()).map(|_| rng.below(spec.num_classes) as i32).collect(),
            y: (0..spec.y_elems()).map(|_| rng.below(spec.num_classes) as i32).collect(),
        },
    }
}

pub struct Prop {
    pub cases: usize,
    pub base_seed: u64,
}

impl Default for Prop {
    fn default() -> Self {
        // COGC_PROP_CASES scales the sweep (CI vs thorough local runs).
        let cases = std::env::var("COGC_PROP_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        Prop { cases, base_seed: 0xC06C_0DE5 }
    }
}

impl Prop {
    pub fn new(cases: usize) -> Self {
        Prop { cases, ..Default::default() }
    }

    /// Run `f(rng, case_index)` for every case; panic with the case seed on
    /// the first failure (any panic inside `f`).
    pub fn forall(&self, name: &str, mut f: impl FnMut(&mut Rng, usize)) {
        for case in 0..self.cases {
            let seed = self.base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
            let mut rng = Rng::new(seed);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                f(&mut rng, case)
            }));
            if let Err(e) = result {
                let msg = e
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".into());
                panic!("property {name:?} failed at case {case} (seed {seed:#x}): {msg}");
            }
        }
    }
}

/// Replay a single failing case.
pub fn forall_seeded(seed: u64, f: impl FnOnce(&mut Rng)) {
    let mut rng = Rng::new(seed);
    f(&mut rng);
}

/// Assert two f64 values are close (relative + absolute tolerance).
#[track_caller]
pub fn assert_close(a: f64, b: f64, tol: f64) {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    assert!(
        (a - b).abs() <= tol * scale,
        "assert_close failed: {a} vs {b} (tol {tol})"
    );
}

/// Assert slices are element-wise close.
#[track_caller]
pub fn assert_allclose(a: &[f64], b: &[f64], tol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0f64.max(x.abs()).max(y.abs());
        assert!(
            (x - y).abs() <= tol * scale,
            "assert_allclose failed at index {i}: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0;
        Prop::new(10).forall("counter", |_, _| count += 1);
        assert_eq!(count, 10);
    }

    #[test]
    fn forall_reports_failure() {
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            Prop::new(10).forall("fails", |_, case| assert!(case < 5));
        }));
        let err = r.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<?>".into());
        assert!(msg.contains("case 5"), "msg: {msg}");
    }

    #[test]
    fn close_helpers() {
        assert_close(1.0, 1.0 + 1e-12, 1e-9);
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-12], 1e-9);
    }
}
