//! Tiny CLI argument parser (substrate — clap is not available offline).
//!
//! Supports `program <subcommand> --key value --flag positionals...` with
//! typed accessors and defaulting. Unknown options are an error so typos
//! fail loudly.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the program name).
    ///
    /// `known_flags` lists boolean options (present/absent, no value); every
    /// other `--key` consumes the next token as its value.
    pub fn parse(
        argv: &[String],
        known_flags: &[&str],
        expect_subcommand: bool,
    ) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if expect_subcommand {
            if let Some(first) = it.peek() {
                if !first.starts_with('-') {
                    out.subcommand = it.next().cloned();
                }
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if known_flags.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let val = it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("option --{name} needs a value"))?;
                    out.options.insert(name.to_string(), val.clone());
                }
            } else {
                out.positionals.push(tok.clone());
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn str_opt(&self, name: &str, default: &str) -> String {
        self.options.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn usize_opt(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn u64_opt(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_opt(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(&argv("fig7 --network 2 --seed 42 --verbose pos1"), &["verbose"], true)
            .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("fig7"));
        assert_eq!(a.usize_opt("network", 1).unwrap(), 2);
        assert_eq!(a.u64_opt("seed", 0).unwrap(), 42);
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals, vec!["pos1"]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&argv("train"), &[], true).unwrap();
        assert_eq!(a.usize_opt("rounds", 100).unwrap(), 100);
        assert_eq!(a.f64_opt("lr", 0.005).unwrap(), 0.005);
        assert_eq!(a.str_opt("model", "mnist_cnn"), "mnist_cnn");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&argv("x --opt"), &[], true).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(&argv("--seed abc"), &[], false).unwrap();
        assert!(a.u64_opt("seed", 0).is_err());
    }
}
