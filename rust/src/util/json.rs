//! Minimal JSON parser/serializer (substrate — no serde available offline).
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null); enough for `artifacts/manifest.json` and
//! experiment config files. Numbers are kept as f64 (i64-exact below 2^53,
//! which covers every integer we store).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style access for required fields.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn serialize(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed for our manifests;
                            // map unpaired surrogates to the replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 sequence
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|_| {
                        JsonError { msg: "invalid utf-8".into(), pos: start }
                    })?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"m": 10, "tr": 2, "models": {"mnist": {"d": 51480,
            "x_shape": [32, 1, 28, 28], "x_dtype": "float32",
            "params": [{"name": "conv1.w", "init": "uniform_fanin", "fan_in": 9}]}},
            "flag": true, "none": null, "neg": -1.5e3}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("m").unwrap().as_usize(), Some(10));
        let d = v.get("models").unwrap().get("mnist").unwrap().get("d").unwrap();
        assert_eq!(d.as_usize(), Some(51480));
        assert_eq!(v.get("neg").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(v.get("flag").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("none"), Some(&Json::Null));
        // serialize -> parse -> identical
        let again = Json::parse(&v.serialize()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA"));
        let rt = Json::parse(&v.serialize()).unwrap();
        assert_eq!(v, rt);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{'a': 1}").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse(r#"{"k": "héllo ✓"}"#).unwrap();
        assert_eq!(v.get("k").unwrap().as_str(), Some("héllo ✓"));
    }

    #[test]
    fn nested_arrays() {
        let v = Json::parse("[[1,2],[3,[4,5]],[]]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[1].as_arr().unwrap()[1].as_arr().unwrap()[0].as_f64(), Some(4.0));
    }
}
