//! Leveled stderr logger (substrate — `log`/`env_logger` style, zero deps).
//!
//! Level comes from `COGC_LOG` (error|warn|info|debug|trace), default `info`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn init_level() -> u8 {
    let lvl = match std::env::var("COGC_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

pub fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l == 255 {
        init_level()
    } else {
        l
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn elapsed() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("[{:9.3}s {:5}] {}", elapsed(), format!("{l:?}").to_uppercase(), args);
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn set_and_check() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
