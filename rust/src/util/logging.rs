//! Leveled stderr logger (substrate — `log`/`env_logger` style, zero deps).
//!
//! Level comes from `COGC_LOG` (error|warn|info|debug|trace), default `info`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

/// Parse a `COGC_LOG` value; `None` means unrecognized (caller warns).
fn parse_level(v: &str) -> Option<Level> {
    match v {
        "error" => Some(Level::Error),
        "warn" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

fn init_level() -> u8 {
    let var = std::env::var("COGC_LOG");
    let (lvl, invalid) = match var.as_deref() {
        Ok(v) => match parse_level(v) {
            Some(l) => (l, None),
            // Typos must not silently demote to info without a trace —
            // warn once (below), then fall back.
            None => (Level::Info, Some(v.to_string())),
        },
        Err(_) => (Level::Info, None),
    };
    let lvl = lvl as u8;
    // One-shot: only the thread that wins the 255→lvl race may warn, so a
    // bad value prints exactly one line no matter how many threads log.
    match LEVEL.compare_exchange(255, lvl, Ordering::Relaxed, Ordering::Relaxed) {
        Ok(_) => {
            if let Some(bad) = invalid {
                eprintln!(
                    "[cogc] warning: COGC_LOG={bad:?} is not one of \
                     error|warn|info|debug|trace; defaulting to info"
                );
            }
            lvl
        }
        Err(current) => current,
    }
}

pub fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l == 255 {
        init_level()
    } else {
        l
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn elapsed() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("[{:9.3}s {:5}] {}", elapsed(), format!("{l:?}").to_uppercase(), args);
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn parse_level_recognizes_all_names_and_rejects_typos() {
        assert_eq!(parse_level("error"), Some(Level::Error));
        assert_eq!(parse_level("warn"), Some(Level::Warn));
        assert_eq!(parse_level("info"), Some(Level::Info));
        assert_eq!(parse_level("debug"), Some(Level::Debug));
        assert_eq!(parse_level("trace"), Some(Level::Trace));
        assert_eq!(parse_level("inf0"), None);
        assert_eq!(parse_level("INFO"), None);
        assert_eq!(parse_level(""), None);
    }

    #[test]
    fn set_and_check() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
