//! Deterministic pseudo-random generation (no external crates).
//!
//! `Rng` is xoshiro256** seeded through SplitMix64 — the standard pairing:
//! SplitMix64 whitens arbitrary user seeds into a full 256-bit state. Every
//! stochastic component of the system (erasure draws, data synthesis,
//! parameter init, partitioning) takes an explicit `Rng`, so every figure is
//! reproducible bit-for-bit from `--seed`.

/// SplitMix64 step: used for seeding and as a cheap standalone generator.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box-Muller transform.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed through SplitMix64 (any seed, including 0, is fine).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (for per-client / per-round streams).
    pub fn split(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli(p): true with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our needs).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply avoids modulo bias to < 2^-64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0).
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with the given mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential(rate) via inversion (mean `1/rate`).
    #[inline]
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0, "exponential rate must be positive");
        -(1.0 - self.f64()).ln() / rate
    }

    /// Gamma(alpha, 1) via Marsaglia–Tsang squeeze (alpha boost for alpha<1).
    pub fn gamma(&mut self, alpha: f64) -> f64 {
        assert!(alpha > 0.0, "gamma shape must be positive");
        if alpha < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let u = 1.0 - self.f64();
            return self.gamma(alpha + 1.0) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = 1.0 - self.f64();
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * 1_k): the CIFAR-style non-IID partition sampler.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..k).map(|_| self.gamma(alpha).max(1e-300)).collect();
        let sum: f64 = v.iter().sum();
        for x in &mut v {
            *x /= sum;
        }
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let mut c = Rng::new(43);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn bernoulli_mean() {
        let mut r = Rng::new(7);
        let n = 200_000;
        let hits = (0..n).filter(|_| r.bernoulli(0.3)).count();
        let mean = hits as f64 / n as f64;
        assert!((mean - 0.3).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(21);
        for &rate in &[0.5, 1.0, 3.0] {
            let n = 100_000;
            let mean = (0..n).map(|_| r.exponential(rate)).sum::<f64>() / n as f64;
            assert!((mean - 1.0 / rate).abs() < 0.05 / rate, "rate={rate} mean={mean}");
        }
    }

    #[test]
    fn gamma_mean_var() {
        let mut r = Rng::new(5);
        for &alpha in &[0.35, 1.0, 4.5] {
            let n = 100_000;
            let xs: Vec<f64> = (0..n).map(|_| r.gamma(alpha)).collect();
            let mean = xs.iter().sum::<f64>() / n as f64;
            assert!((mean - alpha).abs() < 0.05 * alpha.max(1.0), "alpha={alpha} mean={mean}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(9);
        let v = r.dirichlet(0.35, 10);
        assert_eq!(v.len(), 10);
        assert!((v.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(v.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(17);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), 20);
    }

    #[test]
    fn split_streams_differ() {
        let mut root = Rng::new(1);
        let mut a = root.split(0);
        let mut b = root.split(1);
        assert_ne!(
            (0..4).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..4).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }
}
