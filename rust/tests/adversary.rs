//! Byzantine-adversary integration tests: spec round-trips, the
//! fraction-0 identity (an adversary that never corrupts anything leaves
//! every tally and CSV untouched), symbolic-vs-payload audit agreement on
//! hand-built corruptions against the dense small-M oracle, and
//! detection-rate assertions over the built-in `byz-*` scenario grid.

use cogc::gc::{self, GcCode};
use cogc::linalg::Matrix;
use cogc::network::{Network, Realization};
use cogc::parallel::MonteCarlo;
use cogc::scenario::{self, run_scenario, AdversarySpec, Attack, Selection, Surface};
use cogc::util::rng::Rng;

const SEED: u64 = 0xBADC_0DE5;

#[test]
fn adversary_spec_cli_and_json_round_trip() {
    for text in [
        "sign_flip:0.2",
        "noise:0.1:5.0",
        "replace:0.25:3.0",
        "collude:0.3:1.0:c2c:nodetect",
        "sign_flip:0.4:nodetect",
        "replace:0.2:5.0:uplink",
    ] {
        let spec = AdversarySpec::parse_cli(text).unwrap();
        let back = AdversarySpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec, back, "CLI -> JSON -> spec changed for {text:?}");
    }
    // malformed specs fail loudly, not silently
    assert!(AdversarySpec::parse_cli("sign_flip").is_err(), "missing fraction");
    assert!(AdversarySpec::parse_cli("sign_flip:1.5").is_err(), "fraction > 1");
    assert!(AdversarySpec::parse_cli("frobnicate:0.2").is_err(), "unknown attack");
    assert!(AdversarySpec::parse_cli("noise:0.1:bogus").is_err(), "bad param token");
}

#[test]
fn byz_scenarios_round_trip_through_json() {
    for name in ["byz-flip-iid", "byz-c2c-poison", "byz-nodetect", "byz-collude-fade"] {
        let sc = scenario::find(name).unwrap();
        let back = scenario::Scenario::from_json(&sc.to_json()).unwrap();
        assert_eq!(sc.adversary, back.adversary, "{name}");
        assert_eq!(sc.to_json().serialize(), back.to_json().serialize(), "{name}");
    }
}

/// A fraction-0 adversary draws its (empty) malicious set on a private
/// substream and then delegates to the plain trial body, so the full
/// RoundSeries — every count, every channel statistic — is identical to
/// running with no adversary at all. Covers both code families.
#[test]
fn fraction_zero_adversary_is_identical_to_no_adversary() {
    for base in ["iid-moderate", "bursty-c2c"] {
        let mut clean = scenario::find(base).unwrap();
        clean.rounds = 8;
        let mut armed = clean.clone();
        armed.adversary = Some(AdversarySpec::fraction(Attack::SignFlip, 0.0));
        let mc = MonteCarlo::new(SEED).with_threads(2);
        let a = run_scenario(&clean, 150, &mc);
        let b = run_scenario(&armed, 150, &mc);
        assert_eq!(a, b, "{base}: fraction-0 series diverged from the plain engine");
        assert!(b.rounds.iter().all(|r| r.corrupted == 0 && r.detected == 0 && r.poisoned == 0));
    }
    // FR family: the sparse group-scan engine has its own adversarial path
    let mut clean = scenario::find("smoke").unwrap();
    clean.code = cogc::gc::CodeFamily::FractionalRepetition;
    match &mut clean.net {
        scenario::NetworkSpec::Homogeneous { m, .. } => *m = 8,
        scenario::NetworkSpec::Perfect { m } => *m = 8,
    }
    clean.validate().unwrap();
    let mut armed = clean.clone();
    armed.adversary = Some(AdversarySpec::fraction(Attack::Replace { scale: 5.0 }, 0.0));
    let mc = MonteCarlo::new(SEED ^ 1).with_threads(2);
    assert_eq!(run_scenario(&clean, 150, &mc), run_scenario(&armed, 150, &mc));
}

/// The CSV contract of the gating: a clean scenario's table has no
/// integrity columns at all (byte-layout unchanged from the pre-adversary
/// harness), while an adversarial scenario grows exactly the five new
/// columns plus a comment tag.
#[test]
fn clean_csv_has_no_adversary_columns_and_armed_csv_does() {
    let mut clean = scenario::find("iid-moderate").unwrap();
    clean.rounds = 4;
    let clean_csv = cogc::figures::scenario_sweep(&clean, 40, 42, 1).to_csv();
    assert!(!clean_csv.contains("p_corrupted"), "clean CSV grew adversary columns");
    assert!(!clean_csv.contains("adversary="), "clean CSV grew an adversary tag");

    let mut armed = clean.clone();
    armed.adversary = Some(AdversarySpec::fraction(Attack::SignFlip, 0.2));
    let armed_csv = cogc::figures::scenario_sweep(&armed, 40, 42, 1).to_csv();
    for col in ["p_corrupted", "p_detected", "p_poisoned", "mean_excised", "mean_false_excised"] {
        assert!(armed_csv.contains(col), "armed CSV missing column {col}");
    }
    assert!(armed_csv.contains("adversary="), "armed CSV missing the comment tag");
}

/// Stack delivered coded rows across a few lossy attempts at dense small M,
/// replace the payloads of two malicious clients' rows with independent
/// garbage, and audit the stack twice: once against the actual payloads
/// (the production payload-parity closure) and once symbolically from the
/// ground-truth corruption flags (the outage estimators' oracle). The two
/// audits must agree check-for-check.
///
/// Replacement corruption (independent draw per uplinked row) is used
/// because it makes every corrupted-support parity check fail generically;
/// a deterministic corruption (e.g. sign-flip) repeated on two identical
/// copies of the same complete row cancels in their pairwise check, which
/// is exactly why the sim layer audits payloads rather than flags.
#[test]
fn payload_audit_matches_symbolic_oracle_on_hand_built_corruptions() {
    let d = 6;
    let mut exercised = 0usize;
    for (m, s, seed) in [(10usize, 7usize, 3u64), (12, 4, 4), (9, 2, 5)] {
        let mut rng = Rng::new(seed);
        let code = GcCode::generate(m, s, &mut rng);
        let net = Network::homogeneous(m, 0.35, 0.35);
        // client gradients: rows of an M x d matrix
        let grads = Matrix::from_fn(m, d, |_, _| rng.normal());
        let malicious = [1usize, m - 2];

        let mut coeffs = Matrix::zeros(0, m);
        let mut sums = Matrix::zeros(0, d);
        let mut corrupted: Vec<bool> = Vec::new();
        let mut attempts = 0;
        while coeffs.rows < m + 4 && attempts < 20 {
            attempts += 1;
            let att = gc::Attempt::observe(&code, &Realization::sample(&net, &mut rng));
            for &r in &att.delivered {
                let row = att.perturbed.row(r);
                coeffs.push_row(row);
                // honest payload of this uplink: coeff-combination of grads
                let mut payload = vec![0.0f64; d];
                for (k, &c) in row.iter().enumerate() {
                    for (j, p) in payload.iter_mut().enumerate() {
                        *p += c * grads.row(k)[j];
                    }
                }
                if malicious.contains(&r) {
                    for p in payload.iter_mut() {
                        *p = 5.0 * rng.normal();
                    }
                }
                sums.push_row(&payload);
                corrupted.push(malicious.contains(&r));
            }
        }
        if coeffs.rows <= m || !corrupted.iter().any(|&c| c) {
            continue; // no redundancy or no corruption landed; next shape
        }
        exercised += 1;
        let by_payload =
            gc::audit_rows(&coeffs, |combo, kept| gc::payload_check_fails(combo, kept, &sums));
        let by_flags = gc::audit_rows(&coeffs, |combo, kept| {
            gc::symbolic_check_fails(combo, kept, &corrupted)
        });
        assert_eq!(
            by_payload, by_flags,
            "M={m} s={s}: payload audit diverged from the symbolic oracle"
        );
        assert!(by_payload.checks > 0, "M={m} s={s}: stack produced no parity checks");
        if by_payload.alarm {
            assert!(
                by_payload.excised.iter().all(|&i| corrupted[i]),
                "M={m} s={s}: excised an honest row: {:?} corrupted={corrupted:?}",
                by_payload.excised
            );
        }
    }
    assert!(exercised >= 2, "only {exercised} shapes produced an auditable corrupted stack");
}

/// An honest stack never alarms under the payload audit (the floating-point
/// residuals of exact-arithmetic relations sit far below the tolerance).
#[test]
fn honest_payload_stack_never_alarms() {
    let d = 5;
    let mut rng = Rng::new(11);
    let m = 10;
    let code = GcCode::generate(m, 7, &mut rng);
    let net = Network::homogeneous(m, 0.3, 0.3);
    let grads = Matrix::from_fn(m, d, |_, _| rng.normal());
    let mut coeffs = Matrix::zeros(0, m);
    let mut sums = Matrix::zeros(0, d);
    for _ in 0..4 {
        let att = gc::Attempt::observe(&code, &Realization::sample(&net, &mut rng));
        for &r in &att.delivered {
            let row = att.perturbed.row(r);
            coeffs.push_row(row);
            let mut payload = vec![0.0f64; d];
            for (k, &c) in row.iter().enumerate() {
                for (j, p) in payload.iter_mut().enumerate() {
                    *p += c * grads.row(k)[j];
                }
            }
            sums.push_row(&payload);
        }
    }
    assert!(coeffs.rows > m, "stack too thin to exercise any checks");
    let audit =
        gc::audit_rows(&coeffs, |combo, kept| gc::payload_check_fails(combo, kept, &sums));
    assert!(!audit.alarm, "false alarm on honest data: {audit:?}");
    assert!(audit.checks > 0);
    assert_eq!(audit.kept.len(), coeffs.rows);
}

/// Scenario-grid detection rates: uplink sign-flip and replacement attacks
/// are detected in well over half the rounds where corruption reaches the
/// PS, the no-detect baseline never alarms but gets poisoned, and the c2c
/// consistent-substitution surface is the documented blind spot — zero
/// alarms, nonzero poisoning.
#[test]
fn byz_grid_detection_rates() {
    let mc = MonteCarlo::new(SEED).with_threads(2);
    let totals = |name: &str| {
        let mut sc = scenario::find(name).unwrap();
        sc.rounds = 6;
        let series = run_scenario(&sc, 300, &mc);
        let mut c = 0usize;
        let mut det = 0usize;
        let mut poi = 0usize;
        for r in &series.rounds {
            c += r.corrupted;
            det += r.detected;
            poi += r.poisoned;
        }
        (c, det, poi)
    };

    for name in ["byz-flip-iid", "byz-replace"] {
        let (corrupted, detected, poisoned) = totals(name);
        assert!(corrupted > 200, "{name}: corruption too rare ({corrupted}) to assert rates");
        assert!(
            detected as f64 >= 0.5 * corrupted as f64,
            "{name}: detection rate {detected}/{corrupted} below 0.5"
        );
        assert!(poisoned <= corrupted, "{name}: poisoned {poisoned} > corrupted {corrupted}");
    }

    let (corrupted, detected, poisoned) = totals("byz-nodetect");
    assert!(corrupted > 200, "byz-nodetect: corruption too rare ({corrupted})");
    assert_eq!(detected, 0, "byz-nodetect: audit disabled but alarms fired");
    assert!(poisoned > 0, "byz-nodetect: undefended poisoning never landed");

    let (corrupted, detected, poisoned) = totals("byz-c2c-poison");
    assert!(corrupted > 200, "byz-c2c-poison: corruption too rare ({corrupted})");
    assert_eq!(detected, 0, "c2c substitution satisfies every coding relation — no alarms");
    assert!(poisoned > 0, "byz-c2c-poison: blind-spot poisoning never landed");
}

/// Fixed-set selection pins the same clients every trial; a fixed empty set
/// behaves exactly like fraction 0.
#[test]
fn fixed_selection_variants() {
    let mut sc = scenario::find("iid-moderate").unwrap();
    sc.rounds = 5;
    let mc = MonteCarlo::new(SEED ^ 7).with_threads(2);
    let clean = run_scenario(&sc, 120, &mc);

    let mut empty = sc.clone();
    empty.adversary = Some(AdversarySpec {
        attack: Attack::SignFlip,
        selection: Selection::Fixed(vec![]),
        surface: Surface::Uplink,
        detect: true,
    });
    assert_eq!(run_scenario(&empty, 120, &mc), clean, "fixed-empty diverged from clean");

    let mut armed = sc.clone();
    armed.adversary = Some(AdversarySpec {
        attack: Attack::SignFlip,
        selection: Selection::Fixed(vec![0, 3]),
        surface: Surface::Uplink,
        detect: true,
    });
    let series = run_scenario(&armed, 120, &mc);
    let corrupted: usize = series.rounds.iter().map(|r| r.corrupted).sum();
    let detected: usize = series.rounds.iter().map(|r| r.detected).sum();
    assert!(corrupted > 0, "fixed {{0,3}} never corrupted anything");
    assert!(detected > 0, "fixed {{0,3}} never detected");
}
