//! Binary-family contract tests: the exact ±1 decode path against the
//! dense float oracle at sizes where the oracle cannot misjudge.
//!
//! The binary family decodes in exact integer/rational arithmetic — no
//! tolerance band anywhere on the production path. These tests pin that
//! down three ways: an exhaustive small-M sweep of every delivery mask
//! against the float combinator oracle, end-to-end payload recovery
//! through all four channel models, and a source-level assert that the
//! production half of `gc/binary.rs` contains no float-tolerance
//! constants at all.

use cogc::gc::{self, BinaryCode, GcPlusDecoder, IntRref};
use cogc::linalg::Matrix;
use cogc::network::{Network, Realization};
use cogc::scenario::{self, ChannelModel};
use cogc::sim::{self, Decoder, Outcome};
use cogc::util::rng::Rng;

fn channel(kind: usize) -> Box<dyn ChannelModel> {
    let name = ["iid-moderate", "bursty-c2c", "correlated-fade", "straggler-harsh"]
        [kind % 4];
    scenario::find(name).unwrap().channel.build()
}

fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

// ── source-level: no float tolerances on the production path ────────────

#[test]
fn production_half_of_binary_module_has_no_float_tolerances() {
    let src = include_str!("../src/gc/binary.rs");
    let production = src.split("#[cfg(test)]").next().unwrap();
    assert!(
        !production.contains("EPS"),
        "gc/binary.rs production code must not reference a float epsilon"
    );
    assert!(
        !production.contains("1e-"),
        "gc/binary.rs production code must not contain float tolerance literals"
    );
    // the split actually found the test module (the guard above is
    // meaningless if the whole file was scanned)
    assert!(src.contains("#[cfg(test)]"), "binary.rs lost its test module");
}

// ── exhaustive mask sweep vs the dense float oracle ─────────────────────

#[test]
fn combinator_solvability_matches_dense_oracle_on_every_mask() {
    for (m, s) in [(6usize, 2usize), (8, 4), (10, 2)] {
        let code = BinaryCode::new(m, s).unwrap();
        let bridge = code.to_gc_code();
        for mask in 0u32..(1 << m) {
            let complete: Vec<usize> =
                (0..m).filter(|&j| mask & (1 << j) != 0).collect();
            let exact = code.combinator_weights(&complete);
            let oracle = gc::find_combinator(&bridge, &complete);
            assert_eq!(
                exact.is_some(),
                oracle.is_some(),
                "M={m} s={s} mask={mask:b}: exact and float oracle disagree on solvability"
            );
            let Some(w) = exact else { continue };
            // the defining relation: Σ_k w_k · b_{complete[k]} = 1ᵀ
            let mut combo = vec![0.0f64; m];
            for (k, &r) in complete.iter().enumerate() {
                for (j, c) in combo.iter_mut().enumerate() {
                    *c += w[k] * code.coeff(r, j) as f64;
                }
            }
            assert!(
                max_abs_diff(&combo, &vec![1.0; m]) < 1e-9,
                "M={m} s={s} mask={mask:b}: exact combinator violates a·B = 1"
            );
        }
    }
}

// ── end-to-end payload recovery through all four channel models ─────────

#[test]
fn exact_decode_recovers_payloads_across_all_channel_models() {
    let (m, s, d) = (10usize, 4usize, 7usize);
    let code = BinaryCode::new(m, s).unwrap();
    let gcode = code.to_gc_code();
    let mut rng = Rng::new(0xB1AA);
    let mut decoded_any = false;
    for kind in 0..4usize {
        let net = Network::fig6_setting(1 + (kind % 4), m);
        let mut ch = channel(kind);
        ch.reset(&net, 0xF00D + kind as u64);
        let payload = Matrix::from_fn(m, d, |_, _| rng.normal());
        let mut real = Realization::perfect(m);
        let mut stream = Matrix::zeros(0, m);
        let mut ieng = IntRref::new(m);
        let mut feng = GcPlusDecoder::new(m);
        let mut ibuf: Vec<i64> = Vec::new();
        for _ in 0..4 {
            ch.sample_into(&net, &mut rng, &mut real);
            let att = gc::Attempt::observe(&gcode, &real);
            for &r in &att.delivered {
                let row = att.perturbed.row(r);
                stream.push_row(row);
                ibuf.clear();
                ibuf.extend(row.iter().map(|&v| v as i64));
                ieng.push_row(&ibuf);
                feng.push_row(row);
            }
        }
        // exact and float engines agree on the verdict at oracle sizes
        assert_eq!(ieng.rank(), feng.rank(), "channel {kind}: rank");
        let exact_k4: Vec<usize> = ieng.decodable().map(|(c, _)| c).collect();
        assert_eq!(exact_k4, feng.decode().k4, "channel {kind}: K4");
        // and the exact extraction reproduces the ground-truth payloads
        let sums = stream.matmul(&payload);
        let mut w = Vec::new();
        for (client, row) in ieng.decodable() {
            decoded_any = true;
            ieng.t_row_f64(row, &mut w);
            let mut got = vec![0.0f64; d];
            for (k, &wk) in w.iter().enumerate() {
                if wk == 0.0 {
                    continue;
                }
                for (o, v) in got.iter_mut().zip(sums.row(k)) {
                    *o += wk * v;
                }
            }
            assert!(
                max_abs_diff(&got, payload.row(client)) < 1e-8,
                "channel {kind}: client {client} decode drifted from its payload"
            );
        }
    }
    assert!(decoded_any, "no channel produced a decodable client — vacuous test");
}

// ── simulated rounds: outcomes, accounting, exactness ───────────────────

#[test]
fn binary_rounds_partition_account_and_decode_exactly() {
    let (m, s, d) = (10usize, 4usize, 5usize);
    let code = BinaryCode::new(m, s).unwrap();
    for kind in 0..4usize {
        let net = Network::fig6_setting(1 + (kind % 4), m);
        for (decoder, label) in [
            (Decoder::GcPlus { tr: 3 }, "gcplus"),
            (Decoder::Standard { attempts: 3 }, "standard"),
        ] {
            let mut ch = channel(kind);
            ch.reset(&net, 0xACC0 + kind as u64);
            let mut rng = Rng::new(5 + kind as u64);
            for round in 0..15 {
                let out =
                    sim::simulate_round_binary(&net, &mut *ch, code, d, decoder, &mut rng);
                let what = format!("channel {kind} {label} round {round}");
                match (&out.outcome, &out.aggregate) {
                    (Outcome::None, None) => {}
                    (Outcome::None, Some(_)) => panic!("{what}: aggregate without decode"),
                    (_, None) => panic!("{what}: decode without aggregate"),
                    (Outcome::Full | Outcome::Standard { .. }, Some(agg)) => {
                        // exact decode of the full sum: the aggregate IS the
                        // true mean up to float summation noise
                        assert!(
                            max_abs_diff(agg, &out.true_mean) < 1e-8,
                            "{what}: exact full decode drifted from the true mean"
                        );
                    }
                    (Outcome::Partial { k4 }, Some(_)) => {
                        assert!(!k4.is_empty() && k4.len() < m, "{what}: bad K4");
                    }
                }
                assert!(out.decode_err < 1e-8, "{what}: decode_err {}", out.decode_err);
                match decoder {
                    // GC⁺ uplinks all M stacked sums every attempt:
                    // deterministic transmission count
                    Decoder::GcPlus { tr } => assert_eq!(
                        out.transmissions,
                        tr * (s * m + m),
                        "{what}: transmissions"
                    ),
                    // standard observes every attempt before decoding, so
                    // the c2c floor is attempts·s·M; uplinks add at most M
                    // complete rows per attempt
                    Decoder::Standard { attempts } => {
                        assert!(out.transmissions >= attempts * s * m, "{what}: transmissions");
                        assert!(
                            out.transmissions <= attempts * (s * m + m),
                            "{what}: transmissions"
                        );
                    }
                }
            }
        }
    }
}

// ── scratch reuse and bridge-cache invalidation ─────────────────────────

#[test]
fn shared_scratch_matches_fresh_scratch_across_code_switches() {
    // alternate between two different (M, s) codes through ONE scratch —
    // the cached dense bridge must be rebuilt on every switch, never
    // reused stale
    let codes = [
        BinaryCode::new(10, 2).unwrap(),
        BinaryCode::new(6, 2).unwrap(),
        BinaryCode::new(10, 2).unwrap(),
        BinaryCode::new(8, 4).unwrap(),
    ];
    let run = |shared: bool| -> Vec<sim::SimRound> {
        let mut scratch = sim::BinSimScratch::new();
        let mut out = Vec::new();
        for (i, code) in codes.iter().enumerate() {
            let m = code.m;
            let net = Network::homogeneous(m, 0.3, 0.3);
            let mut ch = channel(i);
            ch.reset(&net, 0x5C4A + i as u64);
            let mut rng = Rng::new(100 + i as u64);
            if !shared {
                scratch = sim::BinSimScratch::new();
            }
            out.push(sim::simulate_round_binary_scratch(
                &net,
                &mut *ch,
                *code,
                4,
                Decoder::GcPlus { tr: 2 },
                &mut rng,
                &mut scratch,
            ));
        }
        out
    };
    let shared = run(true);
    let fresh = run(false);
    assert_eq!(shared.len(), fresh.len());
    for (a, b) in shared.iter().zip(&fresh) {
        assert_eq!(a.outcome, b.outcome);
        assert_eq!(a.transmissions, b.transmissions);
        assert_eq!(
            a.aggregate.as_deref().map(|v| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>()),
            b.aggregate.as_deref().map(|v| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>()),
            "aggregates must be bit-identical regardless of scratch reuse"
        );
        assert_eq!(a.decode_err.to_bits(), b.decode_err.to_bits());
    }
}
