//! Cross-family equivalence tests for the structured-code refactor.
//!
//! The fractional-repetition path never touches the dense linear-algebra
//! engine — coverage is an O(M) group scan over a sparse realization. These
//! tests pin that scan to the dense oracle: `FrCode::dense_b()` is the
//! family's actual M×M generator matrix, a group's sum is declared
//! recoverable by `solve_consistent` over the delivered-complete rows, and
//! the two verdicts must agree on *identical* channel draws (the sparse
//! realization is a projection of the dense one) under all four channel
//! models. The oracle deliberately bypasses `find_combinator_rows`: its
//! `received < M − s` early-out is a cyclic-family property — FR decodes
//! from as few as M/(s+1) rows.

use cogc::gc::FrCode;
use cogc::linalg::{solve_consistent, Matrix};
use cogc::network::{Network, Realization, SparseRealization};
use cogc::scenario::{
    ChannelModel, CorrelatedFading, DeadlineStraggler, GilbertElliott, Iid,
};
use cogc::util::rng::Rng;

/// Dense oracle: is `target` (as a row vector) in the span of the
/// delivered-complete rows of the FR generator matrix?
fn dense_spans(code: &FrCode, rows: &[usize], target: &[f64]) -> bool {
    if rows.is_empty() {
        return target.iter().all(|&x| x == 0.0);
    }
    let b = code.dense_b();
    let sub = Matrix::from_fn(rows.len(), code.m, |i, j| b[(rows[i], j)]);
    solve_consistent(&sub.transpose(), target).is_some()
}

/// Rows usable by the PS under FR semantics: uplink up AND every incoming
/// group link up (computed from the *dense* realization directly, so the
/// oracle shares no code with the sparse scan).
fn delivered_complete_rows(code: &FrCode, real: &Realization) -> Vec<usize> {
    (0..code.m)
        .filter(|&i| {
            real.tau[i]
                && code
                    .members(code.group_of(i))
                    .filter(|&j| j != i)
                    .all(|j| real.t[i][j])
        })
        .collect()
}

/// FR decodability identity: *any* M − s delivered-complete rows span 𝟙.
/// (≤ s erasures cannot wipe out a whole group of s+1 identical rows.)
#[test]
fn any_m_minus_s_rows_decode_the_full_sum() {
    for s in [1usize, 2, 3] {
        let m = 12;
        let code = FrCode::new(m, s).unwrap();
        let ones = vec![1.0; m];
        let mut rng = Rng::new(41 + s as u64);
        for _ in 0..200 {
            // drop exactly s random rows; the rest must still span 𝟙
            let mut rows: Vec<usize> = (0..m).collect();
            for _ in 0..s {
                let k = rng.range(0, rows.len());
                rows.remove(k);
            }
            assert!(
                dense_spans(&code, &rows, &ones),
                "m={m} s={s}: dropping to rows {rows:?} lost the full sum"
            );
        }
        // and the minimal support decodes too: one row per group
        let minimal: Vec<usize> = (0..code.groups()).map(|g| g * (s + 1)).collect();
        assert!(dense_spans(&code, &minimal, &ones));
        // while wiping a whole group loses it
        let wiped: Vec<usize> = (s + 1..m).collect();
        assert!(!dense_spans(&code, &wiped, &ones));
    }
}

/// The core identity: the sparse per-group scan agrees with the dense
/// linear-algebra oracle on identical realizations, for every group and
/// for the standard (full-sum) decode, across all four channel models and
/// s ∈ {1, 2, 3}.
#[test]
fn sparse_scan_matches_dense_oracle_all_channels() {
    let m = 12;
    let net = Network::homogeneous(m, 0.35, 0.3);
    let models: Vec<(&str, Box<dyn ChannelModel>)> = vec![
        ("iid", Box::new(Iid)),
        ("ge", Box::new(GilbertElliott::new(0.15, 0.3, (0.5, 2.5), (0.5, 2.0)))),
        ("cf", Box::new(CorrelatedFading::new(0.25, 2.5, 0.5))),
        ("ds", Box::new(DeadlineStraggler::new(2.0, 0.5, 1.0, 0.2, 0.3, 3.0))),
    ];
    for (name, mut ch) in models {
        for s in [1usize, 2, 3] {
            let code = FrCode::new(m, s).unwrap();
            let sup = code.sparse_support();
            let ones = vec![1.0; m];
            let mut rng = Rng::new(7);
            ch.reset(&net, 0xABCD + s as u64);
            for trial in 0..60 {
                let dense = ch.sample(&net, &mut rng);
                let sparse = SparseRealization::project_from_dense(&sup, &dense);
                let covered = code.covered(&sparse, 1);
                let usable = delivered_complete_rows(&code, &dense);
                for g in 0..code.groups() {
                    let target: Vec<f64> = (0..m)
                        .map(|j| (code.group_of(j) == g) as u8 as f64)
                        .collect();
                    assert_eq!(
                        covered[g],
                        dense_spans(&code, &usable, &target),
                        "{name} s={s} trial {trial} group {g}: scan vs oracle"
                    );
                }
                assert_eq!(
                    FrCode::all_covered(&covered),
                    dense_spans(&code, &usable, &ones),
                    "{name} s={s} trial {trial}: standard decode vs oracle"
                );
            }
        }
    }
}

/// The chunked/parallel scan is bit-identical to the serial scan.
#[test]
fn coverage_scan_thread_invariant() {
    let m = 120;
    let s = 3;
    let net = Network::homogeneous(m, 0.4, 0.3);
    let code = FrCode::new(m, s).unwrap();
    let sup = code.sparse_support();
    let mut rng = Rng::new(9);
    for _ in 0..30 {
        let real = SparseRealization::sample(&sup, &net, &mut rng);
        let want = code.covered(&real, 1);
        for threads in [2usize, 4, 8] {
            assert_eq!(code.covered(&real, threads), want, "threads={threads}");
        }
    }
}

/// Degenerate stateful channels collapse to i.i.d. **on the sparse path**:
/// identical probability streams mean byte-identical sparse realizations.
#[test]
fn degenerate_stateful_models_match_iid_sparse_draws() {
    let m = 12;
    let s = 2;
    let net = Network::homogeneous(m, 0.3, 0.25);
    let sup = FrCode::new(m, s).unwrap().sparse_support();
    let degenerates: Vec<(&str, Box<dyn ChannelModel>)> = vec![
        ("ge", Box::new(GilbertElliott::new(0.2, 0.3, (1.0, 1.0), (1.0, 1.0)))),
        ("cf", Box::new(CorrelatedFading::new(0.0, 25.0, 0.9))),
        ("ds", Box::new(DeadlineStraggler::new(f64::INFINITY, 0.5, 1.0, 0.2, 0.2, 3.0))),
    ];
    for (name, mut ch) in degenerates {
        let mut iid: Box<dyn ChannelModel> = Box::new(Iid);
        iid.reset_sparse(&sup, &net, 1);
        ch.reset_sparse(&sup, &net, 1);
        let mut r_iid = SparseRealization::default();
        let mut r_ch = SparseRealization::default();
        let mut rng_a = Rng::new(77);
        let mut rng_b = Rng::new(77);
        for attempt in 0..50 {
            iid.sample_sparse_into(&sup, &net, &mut rng_a, &mut r_iid);
            ch.sample_sparse_into(&sup, &net, &mut rng_b, &mut r_ch);
            assert_eq!(r_ch, r_iid, "{name} attempt {attempt}");
        }
    }
}
