//! Differential decode-equivalence harness: the contract that locks the
//! hybrid peeling decoder and the binary family to the reference engines.
//!
//! Three independent decode paths must agree on every stream this file can
//! draw:
//!
//! 1. **peeling + elimination** (`linalg::PeelingDecoder`, the engine
//!    behind `gc::GcPlusDecoder`) — bit-for-bit equal internal state
//!    (pivots, reduced rows, transforms) to
//! 2. **pure incremental elimination** (`linalg::IncrementalRref`, the
//!    pre-peeling engine) at *every prefix* of the stream, and both to
//! 3. **batch factorization** (`linalg::rref_with_transform`) of the full
//!    stacked matrix: same rank, same decodable set `K₄`, same extraction
//!    weights, same decoded payload sums — to the bit.
//!
//! Streams are drawn across all three code families (cyclic, fractional
//! repetition bridged dense, binary ±1), all four channel models (iid,
//! Gilbert–Elliott, correlated fading, deadline straggler), a random
//! (M, s, attempt-depth) grid, and a seed corpus of degenerate stacks
//! (empty, dead-uplink, duplicate-row, explicit-zero-row). The binary
//! streams additionally pin the exact integer engine's verdicts to the
//! float path at oracle sizes, and the scenario CSVs through the peeling
//! path must stay byte-identical at any `--threads` value.

use cogc::figures;
use cogc::gc::{self, BinaryCode, FrCode, GcCode, GcPlusDecoder, IntRref};
use cogc::linalg::{
    decodable_columns, rref_with_transform, IncrementalRref, Matrix, PeelingDecoder,
};
use cogc::network::{Network, Realization};
use cogc::parallel::MonteCarlo;
use cogc::scenario::{self, run_scenario, ChannelModel};
use cogc::testing::Prop;
use cogc::util::rng::Rng;

// ── helpers ─────────────────────────────────────────────────────────────

fn assert_slice_bits(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: entry {i}: {x} vs {y}");
    }
}

fn assert_matrix_bits(a: &Matrix, b: &Matrix, what: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{what}: shape");
    assert_slice_bits(&a.data, &b.data, what);
}

/// One of the four channel-model kinds, by registry scenario.
fn channel(kind: usize) -> Box<dyn ChannelModel> {
    let name = ["iid-moderate", "bursty-c2c", "correlated-fade", "straggler-harsh"]
        [kind % 4];
    scenario::find(name).unwrap().channel.build()
}

/// The three families as dense codes an `Attempt` can observe. The cyclic
/// draw consumes `rng`; fr/binary are deterministic per (m, s) — their
/// validity constraints are coerced by the caller.
fn family_code(fam: usize, m: usize, s: usize, rng: &mut Rng) -> GcCode {
    match fam % 3 {
        0 => GcCode::generate(m, s, rng),
        1 => {
            let fr = FrCode::new(m, s).unwrap();
            GcCode { m, s, b: fr.dense_b(), h: Matrix::zeros(0, m) }
        }
        _ => BinaryCode::new(m, s).unwrap().to_gc_code(),
    }
}

/// Coerce (m, s) into a shape every family accepts: s even (binary) and
/// m % (s+1) == 0 (fr).
fn family_shape(fam: usize, m_raw: usize, s_raw: usize) -> (usize, usize) {
    match fam % 3 {
        1 => {
            let s = s_raw.clamp(1, m_raw.saturating_sub(1).max(1));
            let m = (m_raw / (s + 1)).max(1) * (s + 1);
            (m.max(s + 1), s)
        }
        2 => {
            let s = (s_raw & !1).max(2);
            (m_raw.max(s + 1), s)
        }
        _ => (m_raw, s_raw.clamp(1, m_raw - 1)),
    }
}

/// The tentpole check: feed `stream` (a stacked row matrix) through the
/// peeling decoder and the pure engine in lockstep, asserting bit-equal
/// internal state at every prefix; then check both against the batch
/// factorization and, when payloads are given, the decoded sums.
fn check_stream(stream: &Matrix, payload: Option<&Matrix>, what: &str) {
    let cols = stream.cols;
    let mut peel = PeelingDecoder::new(cols);
    let mut pure = IncrementalRref::new(cols);
    for r in 0..stream.rows {
        let row = stream.row(r);
        peel.push_row(row);
        pure.push_row(row);
        // per-prefix: same verdict on the row just pushed, same summary
        assert_eq!(peel.rank(), pure.rank(), "{what}: prefix {r}: rank");
        assert_eq!(
            peel.decodable_count(),
            pure.decodable_count(),
            "{what}: prefix {r}: decodable_count"
        );
        assert_slice_bits(
            peel.null_transform(),
            pure.null_transform(),
            &format!("{what}: prefix {r}: null transform"),
        );
    }
    // full internal state, to the bit
    let eng = peel.engine();
    assert_eq!(eng.pivots(), pure.pivots(), "{what}: pivots");
    assert_eq!(eng.rows(), pure.rows(), "{what}: rows_seen");
    for i in 0..pure.rank() {
        assert_slice_bits(eng.e_row(i), pure.e_row(i), &format!("{what}: e row {i}"));
        assert_slice_bits(eng.t_row(i), pure.t_row(i), &format!("{what}: t row {i}"));
    }
    if stream.rows == 0 {
        return;
    }
    // batch factorization of the full stack
    let rr = rref_with_transform(stream);
    assert_eq!(rr.rank, pure.rank(), "{what}: batch rank");
    let batch_dec = decodable_columns(&rr);
    let batch_k4: Vec<usize> = batch_dec.iter().map(|&(c, _)| c).collect();
    let inc_k4: Vec<usize> = pure.decodable().map(|(c, _)| c).collect();
    assert_eq!(inc_k4, batch_k4, "{what}: K4");
    for (&(_, br), &(_, ir)) in batch_dec.iter().zip(pure.decodable().collect::<Vec<_>>().iter())
    {
        assert_slice_bits(
            rr.t.row(br),
            pure.t_row(ir),
            &format!("{what}: extraction weights col-pair ({br},{ir})"),
        );
    }
    // decoded payload sums, through both weight sets
    if let Some(g) = payload {
        let sums = stream.matmul(g);
        let mut w_inc = Matrix::zeros(0, stream.rows);
        for (_, i) in pure.decodable() {
            w_inc.push_row(pure.t_row(i));
        }
        let mut w_batch = Matrix::zeros(0, stream.rows);
        for &(_, r) in &batch_dec {
            w_batch.push_row(rr.t.row(r));
        }
        assert_matrix_bits(
            &w_inc.matmul(&sums),
            &w_batch.matmul(&sums),
            &format!("{what}: decoded sums"),
        );
    }
}

/// Draw `tr` attempts of family `fam` over `net` through channel `ch` and
/// return the delivered-row stack (the decoder's input stream).
fn sample_stream(
    fam: usize,
    m: usize,
    s: usize,
    tr: usize,
    net: &Network,
    ch: &mut dyn ChannelModel,
    rng: &mut Rng,
) -> Matrix {
    let mut stream = Matrix::zeros(0, m);
    let mut real = Realization::perfect(m);
    for _ in 0..tr {
        let code = family_code(fam, m, s, rng);
        ch.sample_into(net, rng, &mut real);
        let att = gc::Attempt::observe(&code, &real);
        for &r in &att.delivered {
            stream.push_row(att.perturbed.row(r));
        }
    }
    stream
}

// ── the random differential sweep ───────────────────────────────────────

#[test]
fn prop_peeling_equals_pure_equals_batch_across_families_and_channels() {
    Prop::new(60).forall("peeling == pure == batch", |rng, trial| {
        let fam = rng.below(3);
        let (m, s) = family_shape(fam, rng.range(4, 13), rng.range(1, 6));
        let tr = rng.range(1, 5);
        let p = rng.uniform(0.05, 0.9);
        let net = Network::homogeneous(m, p, p);
        let mut ch = channel(rng.below(4));
        ch.reset(&net, 0xDEC0 + trial as u64);
        let stream = sample_stream(fam, m, s, tr, &net, &mut *ch, rng);
        let payload = Matrix::from_fn(m, 3, |_, _| rng.normal());
        check_stream(&stream, Some(&payload), &format!("fam {fam} m={m} s={s} tr={tr}"));
    });
}

#[test]
fn gcplus_decoder_decode_matches_batch_decode_bitwise() {
    // the public decoder API (peeling-fronted) against gc::decode on the
    // same stacks — k4, rank, weights, and decoded sums, to the bit
    let mut rng = Rng::new(77);
    for setting in 1..=4 {
        let net = Network::fig6_setting(setting, 10);
        for tr in [1usize, 2, 6] {
            let attempts: Vec<gc::Attempt> = (0..tr)
                .map(|_| {
                    let code = GcCode::generate(10, 7, &mut rng);
                    gc::Attempt::observe(&code, &Realization::sample(&net, &mut rng))
                })
                .collect();
            let stacked = gc::stack_attempts(&attempts);
            let batch = gc::decode(&stacked);
            let mut dec = GcPlusDecoder::new(10);
            for att in &attempts {
                dec.push_attempt(att);
            }
            assert_eq!(dec.rank(), batch.rank);
            assert_eq!(dec.decodable_count(), batch.k4.len());
            let inc = dec.decode();
            assert_eq!(inc.k4, batch.k4);
            assert_matrix_bits(&inc.weights, &batch.weights, "weights");
            if stacked.rows > 0 {
                let payload = Matrix::from_fn(10, 4, |_, _| rng.normal());
                let sums = stacked.matmul(&payload);
                assert_matrix_bits(
                    &inc.weights.matmul(&sums),
                    &batch.weights.matmul(&sums),
                    "decoded sums",
                );
            }
            let (peeled, forwarded) = dec.peel_split();
            assert_eq!(peeled + forwarded, stacked.rows, "peel_split partition");
        }
    }
}

// ── seed corpus: degenerate stacks ──────────────────────────────────────

#[test]
fn seed_corpus_degenerate_stacks() {
    // empty stream
    check_stream(&Matrix::zeros(0, 8), None, "empty");

    // explicit zero rows (all dependent, all peelable as resolved rows)
    let zeros = Matrix::zeros(5, 6);
    check_stream(&zeros, None, "all-zero rows");

    // dead uplinks: attempts that deliver nothing
    let mut rng = Rng::new(4);
    let dead = Network::homogeneous(6, 1.0, 1.0);
    let mut ch = channel(0);
    ch.reset(&dead, 1);
    let stream = sample_stream(0, 6, 2, 3, &dead, &mut *ch, &mut rng);
    assert_eq!(stream.rows, 0, "dead net must deliver nothing");
    check_stream(&stream, None, "dead uplinks");

    // duplicate rows: every repeat is dependent in both engines
    let net = Network::fig6_setting(2, 10);
    let mut ch = channel(0);
    ch.reset(&net, 2);
    let base = sample_stream(0, 10, 7, 2, &net, &mut *ch, &mut rng);
    let mut dup = Matrix::zeros(0, 10);
    for _ in 0..3 {
        for r in 0..base.rows {
            dup.push_row(base.row(r));
        }
    }
    check_stream(&dup, None, "duplicate rows");

    // unit-vector rows (maximally peelable stream)
    let mut units = Matrix::zeros(0, 7);
    for c in [3usize, 0, 6, 3, 1] {
        let mut row = vec![0.0; 7];
        row[c] = 1.0;
        units.push_row(&row);
    }
    check_stream(&units, None, "unit rows");
}

/// Mid-stream equality with a *persistent* engine: the until-decode loop
/// polls after every block; each poll must match a batch factorization of
/// exactly the prefix pushed so far.
#[test]
fn mid_stream_prefixes_match_batch() {
    let mut rng = Rng::new(31);
    for fam in 0..3usize {
        let (m, s) = family_shape(fam, 12, 3);
        let net = Network::homogeneous(m, 0.5, 0.6);
        let mut ch = channel(fam);
        ch.reset(&net, 9 + fam as u64);
        let stream = sample_stream(fam, m, s, 8, &net, &mut *ch, &mut rng);
        let mut peel = PeelingDecoder::new(m);
        for upto in 0..stream.rows {
            peel.push_row(stream.row(upto));
            let mut prefix = Matrix::zeros(0, m);
            for r in 0..=upto {
                prefix.push_row(stream.row(r));
            }
            let rr = rref_with_transform(&prefix);
            assert_eq!(peel.rank(), rr.rank, "fam {fam} prefix {upto}: rank");
            assert_eq!(
                peel.decodable_count(),
                decodable_columns(&rr).len(),
                "fam {fam} prefix {upto}: decodable"
            );
        }
    }
}

// ── binary family: exact engine vs float path ───────────────────────────

#[test]
fn binary_exact_engine_agrees_with_float_path_at_oracle_sizes() {
    // at M <= 10 the float engine's tolerance floors cannot misjudge a ±1
    // stack, so the exact integer verdicts must coincide exactly
    let mut rng = Rng::new(123);
    for trial in 0u64..40 {
        let m = 4 + (trial as usize % 7); // 4..=10
        let s = 2 + 2 * (trial as usize % ((m - 1) / 2).max(1)).min((m - 3) / 2);
        let code = BinaryCode::new(m, s.min(m - 1) & !1).unwrap_or_else(|_| {
            BinaryCode::new(m, 2).unwrap()
        });
        let gcode = code.to_gc_code();
        let p = 0.2 + 0.1 * (trial % 5) as f64;
        let net = Network::homogeneous(m, p, p);
        let mut stream_f = Matrix::zeros(0, m);
        let mut ieng = IntRref::new(m);
        let mut ibuf: Vec<i64> = Vec::new();
        for _ in 0..3 {
            let att = gc::Attempt::observe(&gcode, &Realization::sample(&net, &mut rng));
            for &r in &att.delivered {
                stream_f.push_row(att.perturbed.row(r));
                ibuf.clear();
                ibuf.extend(att.perturbed.row(r).iter().map(|&v| v as i64));
                ieng.push_row(&ibuf);
            }
        }
        let mut peel = PeelingDecoder::new(m);
        for r in 0..stream_f.rows {
            peel.push_row(stream_f.row(r));
        }
        assert_eq!(peel.rank(), ieng.rank(), "trial {trial}: rank");
        let float_k4: Vec<usize> = peel.decodable().map(|(c, _)| c).collect();
        let exact_k4: Vec<usize> = ieng.decodable().map(|(c, _)| c).collect();
        assert_eq!(float_k4, exact_k4, "trial {trial}: K4");
        check_stream(&stream_f, None, &format!("binary trial {trial}"));
    }
}

// ── thread / CSV invariance through the peeling path ────────────────────

#[test]
fn scenario_sweeps_thread_invariant_through_peeling_and_binary_paths() {
    // cyclic scenario (peeling-fronted decoder underneath)
    let sc = scenario::find("iid-moderate").unwrap();
    let want = run_scenario(&sc, 6, &MonteCarlo::new(21).with_threads(1));
    for threads in [2usize, 8] {
        let got = run_scenario(&sc, 6, &MonteCarlo::new(21).with_threads(threads));
        assert_eq!(got, want, "cyclic threads={threads}");
    }
    // binary scenario (exact integer decode underneath)
    let mut sc = scenario::find("smoke").unwrap();
    sc.code = cogc::gc::CodeFamily::Binary;
    sc.s = 2;
    sc.validate().unwrap();
    let want = run_scenario(&sc, 6, &MonteCarlo::new(22).with_threads(1));
    for threads in [2usize, 8] {
        let got = run_scenario(&sc, 6, &MonteCarlo::new(22).with_threads(threads));
        assert_eq!(got, want, "binary threads={threads}");
    }
    // and the CSV surface stays byte-identical
    let reference = figures::scenario_sweep(&sc, 20, 7, 1).to_csv();
    for threads in [2usize, 8] {
        assert_eq!(
            figures::scenario_sweep(&sc, 20, 7, threads).to_csv(),
            reference,
            "csv threads={threads}"
        );
    }
}

// ── audit parity: peeling-backed audit vs pure-engine audit ─────────────

#[test]
fn audit_detection_verdicts_identical_between_engines() {
    // audit_rows runs on the peeling decoder, audit_rows_pure on the bare
    // engine; dependent rows yield bit-identical null transforms either
    // way, so every harvested check — and thus every verdict — must match
    let mut rng = Rng::new(9001);
    for trial in 0u64..20 {
        let m = 6 + (trial as usize % 5);
        let s = 2 + (trial as usize % 3);
        let mut stack = Matrix::zeros(0, m);
        for _ in 0..3 {
            let code = GcCode::generate(m, s.min(m - 1), &mut rng);
            let net = Network::homogeneous(m, 0.3, 0.3);
            let att = gc::Attempt::observe(&code, &Realization::sample(&net, &mut rng));
            for &r in &att.delivered {
                stack.push_row(att.perturbed.row(r));
            }
        }
        // corrupt ~20% of rows so the symbolic audit has something to find
        let corrupted: Vec<bool> =
            (0..stack.rows).map(|_| rng.bernoulli(0.2)).collect();
        for (r, &bad) in corrupted.iter().enumerate() {
            if bad {
                let c = rng.below(m);
                stack.data[r * m + c] += 3.5 + rng.normal().abs();
            }
        }
        let flags = corrupted.clone();
        let peeled = gc::audit_rows(&stack, |combo, kept| {
            gc::symbolic_check_fails(combo, kept, &flags)
        });
        let pure = gc::audit_rows_pure(&stack, |combo, kept| {
            gc::symbolic_check_fails(combo, kept, &flags)
        });
        assert_eq!(peeled, pure, "trial {trial}");
    }
}
