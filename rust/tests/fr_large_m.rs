//! Memory-bound regression test for the structured-family path.
//!
//! The whole point of the fractional-repetition refactor is that nothing on
//! the structured path allocates O(M²): a dense cyclic run at M = 10⁵ would
//! need an M×M generator matrix (~80 GB of f64) and M² link booleans
//! (~10 GB) per realization. This test runs the real scenario engine at
//! M = 10⁵ under an allocation-counting global allocator and asserts the
//! peak stays in the tens-of-megabytes range — any accidental reintroduction
//! of a dense structure blows the bound by two orders of magnitude.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use cogc::gc::{CodeFamily, FrCode};
use cogc::network::{Network, SparseRealization};
use cogc::parallel::MonteCarlo;
use cogc::scenario::{run_scenario, ChannelSpec, NetworkSpec, Scenario};
use cogc::sim::Decoder;
use cogc::util::rng::Rng;

/// Tracks live and peak bytes. The peak update races benignly across
/// threads (compare-and-swap loop), so the reported peak is exact.
struct PeakAlloc;

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for PeakAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let live = LIVE.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            let mut peak = PEAK.load(Ordering::Relaxed);
            while live > peak {
                match PEAK.compare_exchange_weak(peak, live, Ordering::Relaxed, Ordering::Relaxed)
                {
                    Ok(_) => break,
                    Err(cur) => peak = cur,
                }
            }
        }
        p
    }

    unsafe fn dealloc(&self, p: *mut u8, layout: Layout) {
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
        System.dealloc(p, layout);
    }
}

#[global_allocator]
static ALLOC: PeakAlloc = PeakAlloc;

const M: usize = 100_000;
const S: usize = 3;

/// A dense cyclic run at this M would allocate ≥ M²·8 bytes ≈ 80 GB for the
/// generator matrix alone. The sparse path's working set is O(M·(s+1)):
/// realization bits, coverage flags, and per-episode scratch. 256 MB gives
/// the test runner, channel state, and allocator slack two orders of
/// magnitude of headroom while still sitting ~300× below dense.
const PEAK_BOUND: usize = 256 << 20;

#[test]
fn fr_scenario_at_m_1e5_stays_far_below_dense_memory() {
    let sc = Scenario {
        name: "fr-large-m".into(),
        description: "memory regression probe".into(),
        net: NetworkSpec::Homogeneous { m: M, p_ps: 0.3, p_cc: 0.2 },
        channel: ChannelSpec::GilbertElliott {
            p_gb: 0.15,
            p_bg: 0.4,
            c2c_scale: (0.5, 2.0),
            c2s_scale: (0.5, 2.0),
        },
        decoder: Decoder::GcPlus { tr: 2 },
        code: CodeFamily::FractionalRepetition,
        s: S,
        payload_dim: 1,
        rounds: 2,
    };
    sc.validate().expect("large-M FR scenario must validate");

    let before = PEAK.load(Ordering::Relaxed);
    let series = run_scenario(&sc, 3, &MonteCarlo::new(42).with_threads(2));
    let after = PEAK.load(Ordering::Relaxed);

    assert_eq!(series.rounds.len(), sc.rounds);
    for tally in &series.rounds {
        assert_eq!(tally.trials, 3);
        assert_eq!(tally.standard + tally.full + tally.partial + tally.none, 3);
    }

    // Peak is global (includes test-harness startup), so bound the high-water
    // mark reached during the run rather than a delta of live bytes.
    assert!(
        after < PEAK_BOUND,
        "peak allocation {after} bytes (was {before} before the run) exceeds \
         the {PEAK_BOUND}-byte sparse-path budget — something on the FR path \
         is allocating O(M²)"
    );
}

/// Structure-size assertions: the sparse representations really are O(M·k).
#[test]
fn sparse_structures_are_linear_in_m() {
    let net = Network::homogeneous(M, 0.3, 0.2);
    assert!(net.c2c_is_uniform(), "homogeneous nets must not materialize M² link probabilities");

    let code = FrCode::new(M, S).unwrap();
    let sup = code.sparse_support();
    assert_eq!(sup.links(), M * S, "support must hold exactly s in-links per client");

    let mut rng = Rng::new(5);
    let real = SparseRealization::sample(&sup, &net, &mut rng);
    assert_eq!(real.t.len(), M * S);
    assert_eq!(real.tau.len(), M);

    let covered = code.covered(&real, 4);
    assert_eq!(covered.len(), M / (S + 1));
}
