//! Equivalence and determinism guarantees of the incremental decode engine
//! (`linalg::IncrementalRref` / `gc::GcPlusDecoder`):
//!
//! 1. feeding attempts incrementally is **bit-for-bit** equivalent
//!    (`k4`, `weights`, `rank`) to batch-decoding the stacked matrix via
//!    `rref_with_transform` — across random erasure patterns, an M/s grid,
//!    and degenerate (empty / duplicate-row / zero-row) stacks;
//! 2. mid-stream decodes equal batch decodes of the same prefix (the
//!    until-decode loop's per-block poll);
//! 3. the figure CSVs produced through the incremental path stay
//!    byte-identical at any `--threads` value.

use cogc::figures;
use cogc::gc::{self, GcCode, GcPlusDecoder};
use cogc::linalg::{decodable_columns, rref_with_transform, IncrementalRref, Matrix};
use cogc::network::{Network, Realization};
use cogc::scenario;
use cogc::testing::Prop;
use cogc::util::rng::Rng;

fn assert_bits_eq(a: &Matrix, b: &Matrix, what: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{what}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: entry {i}: {x} vs {y}");
    }
}

/// Batch-vs-incremental on one attempt set; returns the stacked height.
fn check_attempts(attempts: &[gc::Attempt], m: usize) -> usize {
    let stacked = gc::stack_attempts(attempts);
    let batch = gc::decode(&stacked);
    let mut dec = GcPlusDecoder::new(m);
    for att in attempts {
        dec.push_attempt(att);
    }
    assert_eq!(dec.rows(), stacked.rows);
    assert_eq!(dec.rank(), batch.rank, "rank");
    assert_eq!(dec.decodable_count(), batch.k4.len(), "decodable_count");
    let inc = dec.decode();
    assert_eq!(inc.k4, batch.k4, "k4");
    assert_bits_eq(&inc.weights, &batch.weights, "weights");

    // and against the batch RREF API itself: the decodable columns of
    // `rref_with_transform` extract with the very same transform rows
    if stacked.rows > 0 {
        let rr = rref_with_transform(&stacked);
        assert_eq!(rr.rank, batch.rank);
        let cols: Vec<usize> = decodable_columns(&rr).iter().map(|&(c, _)| c).collect();
        assert_eq!(cols, batch.k4, "decodable_columns vs decode k4");
        for (i, &(_, r)) in decodable_columns(&rr).iter().enumerate() {
            for (x, y) in rr.t.row(r).iter().zip(batch.weights.row(i)) {
                assert_eq!(x.to_bits(), y.to_bits(), "transform row {r}");
            }
        }
    }
    stacked.rows
}

fn sample_attempts(
    m: usize,
    s: usize,
    tr: usize,
    net: &Network,
    rng: &mut Rng,
) -> Vec<gc::Attempt> {
    (0..tr)
        .map(|_| {
            let code = GcCode::generate(m, s, rng);
            gc::Attempt::observe(&code, &Realization::sample(net, rng))
        })
        .collect()
}

#[test]
fn prop_incremental_equals_batch_across_erasures_and_ms_grid() {
    Prop::new(40).forall("incremental == batch", |rng, _| {
        let m = rng.range(4, 11);
        let s = rng.range(1, m);
        let tr = rng.range(1, 5);
        let p = rng.uniform(0.05, 0.9);
        let net = Network::homogeneous(m, p, p);
        let attempts = sample_attempts(m, s, tr, &net, rng);
        check_attempts(&attempts, m);
    });
}

#[test]
fn incremental_equals_batch_on_paper_settings() {
    let mut rng = Rng::new(9);
    for setting in 1..=4 {
        let net = Network::fig6_setting(setting, 10);
        for tr in [1usize, 2, 6] {
            let attempts = sample_attempts(10, 7, tr, &net, &mut rng);
            check_attempts(&attempts, 10);
        }
    }
}

#[test]
fn degenerate_stacks_agree() {
    // empty stack
    assert_eq!(check_attempts(&[], 10), 0);
    let dec = GcPlusDecoder::new(10);
    assert_eq!(dec.decode().k4, Vec::<usize>::new());

    // all uplinks dead: attempts contribute zero rows
    let mut rng = Rng::new(4);
    let dead = Network::homogeneous(6, 1.0, 1.0);
    let attempts = sample_attempts(6, 2, 3, &dead, &mut rng);
    assert_eq!(check_attempts(&attempts, 6), 0);

    // duplicate rows: pushing the same attempt repeatedly leaves the rank
    // unchanged and still matches batch on the duplicated stack
    let net = Network::fig6_setting(2, 10);
    let base = sample_attempts(10, 7, 2, &net, &mut rng);
    let mut dup = base.clone();
    dup.extend(base.iter().cloned());
    dup.extend(base.iter().cloned());
    check_attempts(&dup, 10);
    let mut one = GcPlusDecoder::new(10);
    for att in &base {
        one.push_attempt(att);
    }
    let rank_once = one.rank();
    for att in &base {
        one.push_attempt(att);
    }
    assert_eq!(one.rank(), rank_once, "duplicate rows must not raise rank");

    // explicit zero rows are dependent
    let mut inc = IncrementalRref::new(5);
    inc.push_rows(&[0.0; 15]);
    assert_eq!(inc.rank(), 0);
    assert_eq!(inc.rows(), 3);
}

/// The until-decode loop's contract: after every block, the incremental
/// engine's decode equals the batch decode of exactly the rows pushed so
/// far — bit for bit, at every prefix.
#[test]
fn mid_stream_decodes_equal_batch_prefixes() {
    let mut rng = Rng::new(31);
    let net = Network::fig6_setting(3, 10);
    let attempts = sample_attempts(10, 7, 10, &net, &mut rng);
    let mut dec = GcPlusDecoder::new(10);
    for upto in 1..=attempts.len() {
        dec.reset(10);
        for att in &attempts[..upto] {
            dec.push_attempt(att);
        }
        let stacked = gc::stack_attempts(&attempts[..upto]);
        let batch = gc::decode(&stacked);
        let inc = dec.decode();
        assert_eq!(inc.k4, batch.k4, "prefix {upto}");
        assert_eq!(inc.rank, batch.rank, "prefix {upto}");
        assert_bits_eq(&inc.weights, &batch.weights, &format!("prefix {upto} weights"));
    }
    // ... and without the reset: one persistent engine fed block by block
    let mut persistent = GcPlusDecoder::new(10);
    for (upto, att) in attempts.iter().enumerate() {
        persistent.push_attempt(att);
        let stacked = gc::stack_attempts(&attempts[..=upto]);
        assert_eq!(
            persistent.decodable_count(),
            gc::decode(&stacked).k4.len(),
            "persistent prefix {}",
            upto + 1
        );
    }
}

#[test]
fn chunked_pushes_match_one_shot_bitwise() {
    let mut rng = Rng::new(55);
    for trial in 0..20 {
        let n = 2 + rng.below(14);
        let m = 2 + rng.below(9);
        let a = Matrix::from_fn(n, m, |_, _| {
            if rng.bernoulli(0.3) { 0.0 } else { rng.normal_ms(0.0, 2.0) }
        });
        let mut one = IncrementalRref::new(m);
        one.push_matrix(&a);
        let mut chunked = IncrementalRref::new(m);
        let mut i = 0;
        while i < n {
            let step = 1 + rng.below(3).min(n - i - 1);
            for r in i..i + step {
                chunked.push_row(a.row(r));
            }
            i += step;
        }
        assert_eq!(one.rank(), chunked.rank(), "trial {trial}");
        assert_eq!(one.pivots(), chunked.pivots(), "trial {trial}");
        for r in 0..one.rank() {
            for (x, y) in one.e_row(r).iter().zip(chunked.e_row(r)) {
                assert_eq!(x.to_bits(), y.to_bits(), "trial {trial} e row {r}");
            }
            for (x, y) in one.t_row(r).iter().zip(chunked.t_row(r)) {
                assert_eq!(x.to_bits(), y.to_bits(), "trial {trial} t row {r}");
            }
        }
    }
}

/// The headline figure CSVs flow through the incremental decoder now; they
/// must stay byte-identical at every thread count.
#[test]
fn fig6_and_scenario_csvs_are_thread_count_invariant_through_incremental_path() {
    let reference = figures::fig6(150, 42, 1).to_csv();
    for threads in [2usize, 8] {
        assert_eq!(figures::fig6(150, 42, threads).to_csv(), reference, "fig6 threads={threads}");
    }
    let mut sc = scenario::find("bursty-c2c").unwrap();
    sc.rounds = 8;
    let reference = figures::scenario_sweep(&sc, 60, 7, 1).to_csv();
    for threads in [2usize, 8] {
        assert_eq!(
            figures::scenario_sweep(&sc, 60, 7, threads).to_csv(),
            reference,
            "scenario threads={threads}"
        );
    }
}
