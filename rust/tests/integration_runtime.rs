//! Integration: PJRT runtime vs the AOT artifacts (requires `make artifacts`).
//!
//! The key numerical contract checked here: the Pallas coded_matmul /
//! sgd_apply artifacts must agree with the native rust implementations to
//! f32 precision — that equivalence is what lets the ablation benches swap
//! implementations freely.

use cogc::linalg::Matrix;
use cogc::runtime::{
    coded::native_combine, Backend, Batch, CodedKernels, CombineImpl, Engine, Manifest,
    ModelRuntime,
};
use cogc::testing::fake_batch;
use cogc::util::rng::Rng;

/// The PJRT artifacts are a build product (`make artifacts`) that a clean
/// checkout does not have, and the engine itself needs real XLA bindings.
/// Skip (with a message) instead of failing when either is unavailable.
fn setup() -> Option<(Engine, Manifest)> {
    match Backend::pjrt_parts() {
        Ok(pair) => Some(pair),
        Err(e) => {
            // a present manifest + working engine means the artifacts are
            // broken, not absent — fail loudly instead of skipping green
            let manifest = cogc::runtime::default_artifacts_dir().join("manifest.json");
            assert!(
                !manifest.exists() || Engine::cpu().is_err(),
                "artifacts present and PJRT available, but setup failed: {e:#}"
            );
            eprintln!("skipping: PJRT backend unavailable: {e:#}");
            None
        }
    }
}

#[test]
fn all_models_load_and_step() {
    let Some((engine, man)) = setup() else { return };
    let mut rng = Rng::new(1);
    for name in ["mnist_cnn", "cifar_cnn", "transformer"] {
        let model = ModelRuntime::load(&engine, &man, name).unwrap();
        let params = model.init_params(&mut rng);
        assert_eq!(params.len(), model.spec.d);
        let batch = fake_batch(&model.spec, &mut rng);
        let (new_params, loss) = model.train_step(&params, &batch, 0, 0.01).unwrap();
        assert_eq!(new_params.len(), params.len());
        assert!(loss.is_finite() && loss > 0.0, "{name}: loss {loss}");
        assert_ne!(new_params, params, "{name}: params did not move");
        let (eloss, correct) = model.eval_step(&params, &batch).unwrap();
        assert!(eloss.is_finite());
        assert!(correct >= 0.0);
    }
}

#[test]
fn repeated_steps_reduce_loss() {
    let Some((engine, man)) = setup() else { return };
    let mut rng = Rng::new(2);
    let model = ModelRuntime::load(&engine, &man, "mnist_cnn").unwrap();
    let mut params = model.init_params(&mut rng);
    // strongly separable batch: distinct random pattern per class
    let spec = &model.spec;
    let b = spec.batch;
    let elems = spec.x_elems() / b;
    let means: Vec<Vec<f32>> = (0..10)
        .map(|_| (0..elems).map(|_| rng.normal() as f32).collect())
        .collect();
    let y: Vec<i32> = (0..b).map(|i| (i % 10) as i32).collect();
    let x: Vec<f32> = (0..b)
        .flat_map(|i| {
            means[y[i] as usize]
                .iter()
                .map(|&mu| 2.0 * mu + 0.3 * rng.normal() as f32)
                .collect::<Vec<_>>()
        })
        .collect();
    let batch = Batch::Image { x, y };
    let mut first = None;
    let mut last = 0.0;
    for i in 0..80 {
        let (p, loss) = model.train_step(&params, &batch, i, 0.02).unwrap();
        params = p;
        if first.is_none() {
            first = Some(loss);
        }
        last = loss;
    }
    assert!(
        last < 0.65 * first.unwrap(),
        "loss {} -> {last}",
        first.unwrap()
    );
}

#[test]
fn pallas_coded_matmul_matches_native() {
    let Some((engine, man)) = setup() else { return };
    let mut rng = Rng::new(3);
    for name in ["mnist_cnn", "transformer"] {
        let spec = man.model(name).unwrap();
        let d = spec.d;
        let pallas = CodedKernels::load(&engine, &man, spec, CombineImpl::Pallas).unwrap();
        // random sparse-ish weights like a perturbed B
        let w = Matrix::from_fn(man.m, man.m, |i, j| {
            if i == j || rng.bernoulli(0.6) {
                rng.normal()
            } else {
                0.0
            }
        });
        let grads: Vec<f32> = (0..man.m * d).map(|_| rng.normal() as f32).collect();
        let got = pallas.encode(&w, &grads).unwrap();
        let want = native_combine(&w, &grads, d);
        assert_eq!(got.len(), want.len());
        let max_err = got
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        // both accumulate in f32 over K=10 terms; tiny tolerance
        assert!(max_err < 2e-3, "{name} encode: max err {max_err}");

        // decode shape [M, MT]
        let wd = Matrix::from_fn(man.m, man.mt, |_, _| {
            if rng.bernoulli(0.3) {
                rng.normal()
            } else {
                0.0
            }
        });
        let stacked: Vec<f32> = (0..man.mt * d).map(|_| rng.normal() as f32).collect();
        let got = pallas.decode(&wd, &stacked).unwrap();
        let want = native_combine(&wd, &stacked, d);
        let max_err = got
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 2e-3, "{name} decode: max err {max_err}");
    }
}

#[test]
fn sgd_artifact_matches_native_axpy() {
    let Some((engine, man)) = setup() else { return };
    let mut rng = Rng::new(4);
    let model = ModelRuntime::load(&engine, &man, "mnist_cnn").unwrap();
    let d = model.spec.d;
    let p: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let g: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    for lr in [0.0f32, 0.5, -1.0] {
        let got = model.sgd_apply(&p, &g, lr).unwrap();
        for i in (0..d).step_by(997) {
            let want = p[i] - lr * g[i];
            assert!((got[i] - want).abs() < 1e-6, "lr={lr} i={i}: {} vs {want}", got[i]);
        }
    }
}

#[test]
fn init_params_follow_schemes() {
    let Some((engine, man)) = setup() else { return };
    let model = ModelRuntime::load(&engine, &man, "transformer").unwrap();
    let mut rng = Rng::new(5);
    let params = model.init_params(&mut rng);
    // layernorm gains are exactly 1, biases exactly 0
    let mut off = 0;
    for p in &model.spec.params {
        let n = p.size();
        let slice = &params[off..off + n];
        match p.init.as_str() {
            "ones" => assert!(slice.iter().all(|&x| x == 1.0), "{} not ones", p.name),
            "zeros" => assert!(slice.iter().all(|&x| x == 0.0), "{} not zeros", p.name),
            "uniform_fanin" => {
                let bound = 1.0 / (p.fan_in as f32).sqrt();
                assert!(slice.iter().all(|&x| x.abs() <= bound + 1e-6), "{} exceeds bound", p.name);
            }
            _ => {}
        }
        off += n;
    }
}

#[test]
fn dropout_seed_changes_mnist_loss() {
    let Some((engine, man)) = setup() else { return };
    let mut rng = Rng::new(6);
    let model = ModelRuntime::load(&engine, &man, "mnist_cnn").unwrap();
    let params = model.init_params(&mut rng);
    let batch = fake_batch(&model.spec, &mut rng);
    let (_, l0) = model.train_step(&params, &batch, 0, 0.0).unwrap();
    let (_, l1) = model.train_step(&params, &batch, 99, 0.0).unwrap();
    assert_ne!(l0, l1, "dropout seed had no effect");
    // and the same seed is bit-deterministic
    let (_, l0b) = model.train_step(&params, &batch, 0, 0.0).unwrap();
    assert_eq!(l0, l0b);
}
