//! Integration: the full CoGC training loop over the model runtime.
//!
//! Most tests run on the native pure-rust backend, which needs no
//! artifacts — they exercise every aggregator end-to-end on a clean
//! offline checkout. The Pallas-vs-native combine comparison still needs
//! `make artifacts` + real PJRT bindings and skips (with a message) when
//! they are unavailable. Tiny round counts — the figure harnesses run the
//! full-scale versions.

use cogc::coordinator::{Aggregator, Design, TrainConfig, Trainer};
use cogc::figures;
use cogc::network::Network;
use cogc::runtime::{Backend, CombineImpl};

fn tiny_cfg(agg: Aggregator, rounds: usize) -> TrainConfig {
    let mut cfg = TrainConfig::new("mnist_cnn", agg);
    cfg.rounds = rounds;
    cfg.per_client = 40;
    cfg.eval_batches = 2;
    cfg.seed = 11;
    cfg
}

#[test]
fn every_aggregator_runs() {
    let backend = Backend::native();
    let m = backend.manifest().m;
    let net = Network::homogeneous(m, 0.3, 0.3);
    for agg in [
        Aggregator::Ideal,
        Aggregator::Intermittent,
        Aggregator::CoGc { design: Design::SkipRound, attempts: 1 },
        Aggregator::CoGc { design: Design::RetryUntilSuccess, attempts: 50 },
        Aggregator::GcPlus { tr: 2, until_decode: false, max_blocks: 1 },
        Aggregator::GcPlus { tr: 2, until_decode: true, max_blocks: 10 },
        Aggregator::TandonReplicated { attempts: 1 },
    ] {
        let mut trainer = Trainer::new(&backend, tiny_cfg(agg, 2), net.clone()).unwrap();
        let log = trainer.run().unwrap();
        assert_eq!(log.rounds.len(), 2, "{agg:?}");
        for rec in &log.rounds {
            assert!(rec.train_loss.is_finite(), "{agg:?}: bad loss");
            assert!(rec.k4 <= m);
            assert_eq!(rec.updated, rec.k4 > 0, "{agg:?}: updated/k4 mismatch");
            // standard GC is binary: all-or-nothing
            if matches!(agg, Aggregator::CoGc { .. } | Aggregator::TandonReplicated { .. }) {
                assert!(rec.k4 == 0 || rec.k4 == m, "{agg:?}: k4={} not binary", rec.k4);
            }
        }
    }
}

#[test]
fn every_model_trains_natively() {
    let backend = Backend::native();
    let m = backend.manifest().m;
    for model in ["mnist_cnn", "cifar_cnn", "transformer"] {
        let mut cfg = TrainConfig::new(model, Aggregator::Ideal);
        cfg.rounds = 2;
        cfg.per_client = if model == "transformer" { 4000 } else { 40 };
        cfg.eval_batches = 2;
        cfg.seed = 3;
        let mut trainer = Trainer::new(&backend, cfg, Network::perfect(m)).unwrap();
        let log = trainer.run().unwrap();
        assert_eq!(log.rounds.len(), 2, "{model}");
        assert!(log.rounds.iter().all(|r| r.train_loss.is_finite()), "{model}: bad loss");
        assert!(log.final_acc().is_finite(), "{model}: bad accuracy");
    }
}

#[test]
fn deterministic_given_seed() {
    let backend = Backend::native();
    let net = Network::homogeneous(backend.manifest().m, 0.2, 0.2);
    let agg = Aggregator::CoGc { design: Design::SkipRound, attempts: 1 };
    let run = || {
        let mut t = Trainer::new(&backend, tiny_cfg(agg, 3), net.clone()).unwrap();
        t.run().unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.to_csv(), b.to_csv(), "same seed must give identical logs");
}

#[test]
fn pallas_and_native_combine_agree_end_to_end() {
    // the one remaining artifact-dependent test: compares the Pallas
    // coded-combine kernels against the native rust combine
    let backend = match Backend::pjrt() {
        Ok(b) => b,
        Err(e) => {
            eprintln!("skipping: PJRT backend unavailable: {e:#}");
            return;
        }
    };
    let net = Network::homogeneous(backend.manifest().m, 0.3, 0.4);
    let agg = Aggregator::GcPlus { tr: 2, until_decode: false, max_blocks: 1 };
    let mut logs = Vec::new();
    for imp in [CombineImpl::Pallas, CombineImpl::Native] {
        let mut cfg = tiny_cfg(agg, 3);
        cfg.combine = imp;
        let mut t = Trainer::new(&backend, cfg, net.clone()).unwrap();
        logs.push(t.run().unwrap());
    }
    // identical round structure and near-identical numbers (both f32 paths,
    // different summation orders under XLA fusion)
    for (a, b) in logs[0].rounds.iter().zip(&logs[1].rounds) {
        assert_eq!(a.outcome, b.outcome);
        assert_eq!(a.k4, b.k4);
        assert_eq!(a.transmissions, b.transmissions);
        assert!(
            (a.train_loss - b.train_loss).abs() < 1e-3,
            "loss diverged: {} vs {}",
            a.train_loss,
            b.train_loss
        );
    }
}

#[test]
fn ideal_training_learns_synthetic_classes() {
    let backend = Backend::native();
    let mut cfg = tiny_cfg(Aggregator::Ideal, 20);
    cfg.per_client = 100;
    cfg.signal = 3.0;
    cfg.eval_batches = 4;
    let mut t = Trainer::new(&backend, cfg, Network::perfect(backend.manifest().m)).unwrap();
    let log = t.run().unwrap();
    let early = log.rounds[0].test_acc;
    let late = log.best_acc();
    assert!(
        late > early + 0.2 && late > 0.4,
        "no learning signal: acc {early:.3} -> {late:.3}"
    );
}

#[test]
fn design1_retries_until_success() {
    let backend = Backend::native();
    // harsh uplinks: single attempts usually fail, Design 1 must still update
    let net = Network::homogeneous(backend.manifest().m, 0.6, 0.1);
    let agg = Aggregator::CoGc { design: Design::RetryUntilSuccess, attempts: 100 };
    let mut t = Trainer::new(&backend, tiny_cfg(agg, 5), net).unwrap();
    let log = t.run().unwrap();
    assert_eq!(log.updates(), 5, "Design 1 must recover every round");
    // and it should have needed more than one attempt somewhere
    assert!(log.rounds.iter().any(|r| r.attempts > 1));
}

#[test]
fn run_until_acc_truncates() {
    let backend = Backend::native();
    let mut cfg = tiny_cfg(Aggregator::Ideal, 30);
    cfg.signal = 3.0;
    cfg.per_client = 100;
    let mut t = Trainer::new(&backend, cfg, Network::perfect(backend.manifest().m)).unwrap();
    let log = t.run_until_acc(0.3).unwrap();
    assert!(log.rounds.len() <= 30);
    if let Some(r) = log.rounds_to_acc(0.3) {
        assert_eq!(r, log.rounds.last().unwrap().round);
    }
}

/// ISSUE-level guarantee: the fig7 training grid emits byte-identical CSV
/// for 1 vs N worker threads and across two identical runs.
#[test]
fn fig7_grid_is_deterministic_across_threads_and_runs() {
    let backend = Backend::native();
    let serial = figures::fig7_8(&backend, "mnist_cnn", 1, 2, 7, 1).unwrap().to_csv();
    let wide = figures::fig7_8(&backend, "mnist_cnn", 1, 2, 7, 8).unwrap().to_csv();
    assert_eq!(serial, wide, "thread count changed the fig7 CSV");
    let again = figures::fig7_8(&backend, "mnist_cnn", 1, 2, 7, 8).unwrap().to_csv();
    assert_eq!(wide, again, "repeated run changed the fig7 CSV");
    // sanity: three methods -> round + 3x(acc, loss) columns, 2 data rows
    let mut lines = serial.lines();
    let _comment = lines.next().unwrap();
    let header = lines.next().unwrap();
    assert_eq!(header.split(',').count(), 7, "unexpected fig7 header: {header}");
    assert_eq!(lines.count(), 2);
}

/// Smoke test mirroring `examples/quickstart.rs`: the quickstart config
/// must complete offline on the native backend and produce sane output.
#[test]
fn quickstart_config_runs_offline() {
    let backend = Backend::auto();
    let m = backend.manifest().m;
    let net = Network::homogeneous(m, 0.1, 0.1);
    let mut cfg = TrainConfig::new(
        "mnist_cnn",
        Aggregator::CoGc { design: Design::SkipRound, attempts: 1 },
    );
    cfg.rounds = 6;
    cfg.seed = 7;
    cfg.per_client = 40;
    cfg.eval_batches = 2;
    let mut trainer = Trainer::new(&backend, cfg, net).unwrap();
    let log = trainer.run().unwrap();
    assert_eq!(log.rounds.len(), 6);
    assert!(log.rounds.iter().all(|r| r.train_loss.is_finite()));
    assert!(log.final_acc().is_finite());
    // at p = 0.1 per link and s = 7, outage is rare: expect recoveries
    assert!(log.updates() >= 1, "no exact recovery in 6 quickstart rounds");
    assert!(log.total_transmissions() > 0);
}
