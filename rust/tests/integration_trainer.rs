//! Integration: the full CoGC training loop over the PJRT runtime
//! (requires `make artifacts`). Tiny round counts — the figure harnesses
//! run the full-scale versions.

use cogc::coordinator::{Aggregator, Design, TrainConfig, Trainer};
use cogc::network::Network;
use cogc::runtime::{default_artifacts_dir, CombineImpl, Engine, Manifest};

/// Skip (with a message) when the AOT artifacts or the real PJRT bindings
/// are unavailable — a clean checkout has neither (`make artifacts`).
fn setup() -> Option<(Engine, Manifest)> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!(
            "skipping: no artifacts manifest at {} — run `make artifacts` first",
            dir.display()
        );
        return None;
    }
    let engine = match Engine::cpu() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: PJRT engine unavailable: {e:#}");
            return None;
        }
    };
    Some((engine, Manifest::load(&dir).unwrap()))
}

fn tiny_cfg(agg: Aggregator, rounds: usize) -> TrainConfig {
    let mut cfg = TrainConfig::new("mnist_cnn", agg);
    cfg.rounds = rounds;
    cfg.per_client = 40;
    cfg.eval_batches = 2;
    cfg.seed = 11;
    cfg
}

#[test]
fn every_aggregator_runs() {
    let Some((engine, man)) = setup() else { return };
    let net = Network::homogeneous(man.m, 0.3, 0.3);
    for agg in [
        Aggregator::Ideal,
        Aggregator::Intermittent,
        Aggregator::CoGc { design: Design::SkipRound, attempts: 1 },
        Aggregator::CoGc { design: Design::RetryUntilSuccess, attempts: 50 },
        Aggregator::GcPlus { tr: 2, until_decode: false, max_blocks: 1 },
        Aggregator::GcPlus { tr: 2, until_decode: true, max_blocks: 10 },
        Aggregator::TandonReplicated { attempts: 1 },
    ] {
        let mut trainer = Trainer::new(&engine, &man, tiny_cfg(agg, 2), net.clone()).unwrap();
        let log = trainer.run().unwrap();
        assert_eq!(log.rounds.len(), 2, "{agg:?}");
        for rec in &log.rounds {
            assert!(rec.train_loss.is_finite(), "{agg:?}: bad loss");
            assert!(rec.k4 <= man.m);
            assert_eq!(rec.updated, rec.k4 > 0, "{agg:?}: updated/k4 mismatch");
            // standard GC is binary: all-or-nothing
            if matches!(agg, Aggregator::CoGc { .. } | Aggregator::TandonReplicated { .. }) {
                assert!(rec.k4 == 0 || rec.k4 == man.m, "{agg:?}: k4={} not binary", rec.k4);
            }
        }
    }
}

#[test]
fn deterministic_given_seed() {
    let Some((engine, man)) = setup() else { return };
    let net = Network::homogeneous(man.m, 0.2, 0.2);
    let agg = Aggregator::CoGc { design: Design::SkipRound, attempts: 1 };
    let run = |engine: &Engine| {
        let mut t = Trainer::new(engine, &man, tiny_cfg(agg, 3), net.clone()).unwrap();
        t.run().unwrap()
    };
    let a = run(&engine);
    let b = run(&engine);
    assert_eq!(a.to_csv(), b.to_csv(), "same seed must give identical logs");
}

#[test]
fn pallas_and_native_combine_agree_end_to_end() {
    let Some((engine, man)) = setup() else { return };
    let net = Network::homogeneous(man.m, 0.3, 0.4);
    let agg = Aggregator::GcPlus { tr: 2, until_decode: false, max_blocks: 1 };
    let mut logs = Vec::new();
    for imp in [CombineImpl::Pallas, CombineImpl::Native] {
        let mut cfg = tiny_cfg(agg, 3);
        cfg.combine = imp;
        let mut t = Trainer::new(&engine, &man, cfg, net.clone()).unwrap();
        logs.push(t.run().unwrap());
    }
    // identical round structure and near-identical numbers (both f32 paths,
    // different summation orders under XLA fusion)
    for (a, b) in logs[0].rounds.iter().zip(&logs[1].rounds) {
        assert_eq!(a.outcome, b.outcome);
        assert_eq!(a.k4, b.k4);
        assert_eq!(a.transmissions, b.transmissions);
        assert!(
            (a.train_loss - b.train_loss).abs() < 1e-3,
            "loss diverged: {} vs {}",
            a.train_loss,
            b.train_loss
        );
    }
}

#[test]
fn ideal_training_learns_synthetic_classes() {
    let Some((engine, man)) = setup() else { return };
    let mut cfg = tiny_cfg(Aggregator::Ideal, 20);
    cfg.per_client = 100;
    cfg.signal = 3.0;
    cfg.eval_batches = 4;
    let mut t = Trainer::new(&engine, &man, cfg, Network::perfect(man.m)).unwrap();
    let log = t.run().unwrap();
    let early = log.rounds[0].test_acc;
    let late = log.best_acc();
    assert!(
        late > early + 0.2 && late > 0.4,
        "no learning signal: acc {early:.3} -> {late:.3}"
    );
}

#[test]
fn design1_retries_until_success() {
    let Some((engine, man)) = setup() else { return };
    // harsh uplinks: single attempts usually fail, Design 1 must still update
    let net = Network::homogeneous(man.m, 0.6, 0.1);
    let agg = Aggregator::CoGc { design: Design::RetryUntilSuccess, attempts: 100 };
    let mut t = Trainer::new(&engine, &man, tiny_cfg(agg, 3), net).unwrap();
    let log = t.run().unwrap();
    assert_eq!(log.updates(), 3, "Design 1 must recover every round");
    // and it should have needed more than one attempt somewhere
    assert!(log.rounds.iter().any(|r| r.attempts > 1));
}

#[test]
fn run_until_acc_truncates() {
    let Some((engine, man)) = setup() else { return };
    let mut cfg = tiny_cfg(Aggregator::Ideal, 30);
    cfg.signal = 3.0;
    cfg.per_client = 100;
    let mut t = Trainer::new(&engine, &man, cfg, Network::perfect(man.m)).unwrap();
    let log = t.run_until_acc(0.3).unwrap();
    assert!(log.rounds.len() <= 30);
    if let Some(r) = log.rounds_to_acc(0.3) {
        assert_eq!(r, log.rounds.last().unwrap().round);
    }
}
