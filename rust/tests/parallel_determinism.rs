//! Determinism regression tests for the parallel Monte-Carlo engine: the
//! ISSUE-level guarantee is that `threads ∈ {1, 2, 8}` produce tallies
//! **bit-identical** to the serial reference for a fixed seed, and that the
//! per-worker accumulator merge is order-independent (so the guarantee
//! survives any work-stealing schedule).

use cogc::gc::{self, GcCode};
use cogc::network::{Network, Realization};
use cogc::outage::mc::{estimate_outage, gcplus_recovery, RecoveryMode, RecoveryStats};
use cogc::parallel::{trial_rng, Accumulate, MonteCarlo};
use cogc::scenario::{self, run_scenario, Iid};
use cogc::sim::{self, Decoder, SweepStats};
use cogc::util::rng::Rng;

const SEED: u64 = 0xD15C_0DE5;
const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

/// Fig. 4 shape (M=10, s=7, ≥2000 trials): outage tallies must match a
/// hand-rolled loop that re-implements the engine's per-trial seeding
/// scheme (`Rng::new(seed ^ trial)`) with no parallel machinery at all.
#[test]
fn outage_estimate_is_bit_identical_across_thread_counts() {
    let net = Network::fig6_setting(2, 10);
    let code = GcCode::generate(10, 7, &mut Rng::new(1));
    let trials = 2_500usize;

    let mut outages = 0usize;
    for t in 0..trials {
        let mut rng = trial_rng(SEED, t as u64);
        let att = gc::Attempt::observe(&code, &Realization::sample(&net, &mut rng));
        if att.complete.len() < 10 - 7 {
            outages += 1;
        }
    }
    let reference = outages as f64 / trials as f64;
    assert!(reference > 0.0 && reference < 1.0, "degenerate reference {reference}");

    for threads in THREAD_COUNTS {
        let mc = MonteCarlo::new(SEED).with_threads(threads);
        let got = estimate_outage(&net, &code, &Iid, trials, &mc);
        assert_eq!(
            got.to_bits(),
            reference.to_bits(),
            "threads={threads}: {got} vs serial reference {reference}"
        );
    }
}

/// Fig. 6 shape (M=10, s=7, 2000 trials, both repetition modes): the full
/// RecoveryStats — including the |K₄| histogram — must be identical at
/// every thread count *and* every chunk size.
#[test]
fn recovery_tallies_are_identical_across_thread_counts_and_chunks() {
    for (stream, mode) in [
        RecoveryMode::FixedTr(2),
        RecoveryMode::UntilDecode { tr: 2, max_blocks: 40 },
    ]
    .into_iter()
    .enumerate()
    {
        let net = Network::fig6_setting(2, 10);
        let seed = SEED + stream as u64;
        let trials = 2_000;
        let reference =
            gcplus_recovery(&net, &Iid, 10, 7, mode, trials, &MonteCarlo::serial(seed));
        assert_eq!(reference.trials, trials);
        assert_eq!(
            reference.standard + reference.full + reference.partial + reference.none,
            trials
        );
        for threads in THREAD_COUNTS {
            for chunk in [1usize, 64, 256] {
                let mc = MonteCarlo::new(seed).with_threads(threads).with_chunk(chunk);
                let got = gcplus_recovery(&net, &Iid, 10, 7, mode, trials, &mc);
                assert_eq!(got, reference, "mode {mode:?} threads={threads} chunk={chunk}");
            }
        }
    }
}

/// The sim-layer sweep (payload decode included) is thread-count invariant,
/// down to the f64 max-decode-error field.
#[test]
fn sim_sweep_is_bit_identical_across_thread_counts() {
    let net = Network::homogeneous(10, 0.4, 0.4);
    let run = |threads: usize| {
        sim::sweep(
            &net,
            &Iid,
            10,
            7,
            6,
            Decoder::GcPlus { tr: 2 },
            600,
            &MonteCarlo::new(SEED).with_threads(threads),
        )
    };
    let reference = run(1);
    assert_eq!(reference.trials, 600);
    for threads in THREAD_COUNTS {
        assert_eq!(run(threads), reference, "threads={threads}");
    }
}

/// Property test: merging per-worker RecoveryStats in any order yields the
/// same total — counts, sums, and histogram buckets are commutative and
/// associative, which is what licenses the engine's work-stealing schedule.
#[test]
fn recovery_stats_merge_is_order_independent() {
    let net = Network::fig6_setting(1, 10);
    let parts: Vec<RecoveryStats> = (0..12u64)
        .map(|c| {
            gcplus_recovery(
                &net,
                &Iid,
                10,
                7,
                RecoveryMode::FixedTr(2),
                40,
                &MonteCarlo::serial(SEED ^ (c << 20)),
            )
        })
        .collect();
    let fold = |order: &[usize]| {
        let mut total = RecoveryStats::default();
        for &i in order {
            total.merge(parts[i].clone());
        }
        total
    };
    let base: Vec<usize> = (0..parts.len()).collect();
    let want = fold(&base);
    assert_eq!(want.trials, 12 * 40);
    let mut rng = Rng::new(3);
    for _ in 0..25 {
        let mut order = base.clone();
        rng.shuffle(&mut order);
        assert_eq!(fold(&order), want, "order {order:?}");
    }
}

/// Same property for the sim-layer SweepStats: its float field is a
/// maximum (order-independent), never an order-sensitive sum.
#[test]
fn sweep_stats_merge_is_order_independent() {
    let net = Network::homogeneous(8, 0.3, 0.3);
    let parts: Vec<SweepStats> = (0..10u64)
        .map(|c| {
            sim::sweep(
                &net,
                &Iid,
                8,
                3,
                5,
                Decoder::GcPlus { tr: 2 },
                30,
                &MonteCarlo::serial(SEED ^ (c << 24)),
            )
        })
        .collect();
    let fold = |order: &[usize]| {
        let mut total = SweepStats::default();
        for &i in order {
            total.merge(parts[i].clone());
        }
        total
    };
    let base: Vec<usize> = (0..parts.len()).collect();
    let want = fold(&base);
    assert_eq!(want.trials, 10 * 30);
    let mut rng = Rng::new(7);
    for _ in 0..25 {
        let mut order = base.clone();
        rng.shuffle(&mut order);
        assert_eq!(fold(&order), want, "order {order:?}");
    }
}

/// The figure harnesses themselves (the CSV the paper plots) must emit the
/// same bytes at 1 and N threads.
#[test]
fn fig4_and_fig6_tables_are_thread_count_invariant() {
    let fig4_serial = cogc::figures::fig4(600, 42, 1).to_csv();
    let fig4_par = cogc::figures::fig4(600, 42, 4).to_csv();
    assert_eq!(fig4_serial, fig4_par);

    let fig6_serial = cogc::figures::fig6(120, 42, 1).to_csv();
    let fig6_par = cogc::figures::fig6(120, 42, 4).to_csv();
    assert_eq!(fig6_serial, fig6_par);
}

/// Scenario sweeps — stateful channel models included — must produce
/// bit-identical RoundSeries and byte-identical CSV at threads 1/2/8: the
/// per-trial channel state is derived from the trial's substream, never
/// from worker identity or schedule.
#[test]
fn scenario_sweeps_are_bit_identical_across_thread_counts() {
    for name in ["iid-moderate", "bursty-c2c", "correlated-fade", "straggler-harsh"] {
        let mut sc = scenario::find(name).unwrap();
        sc.rounds = 10; // keep the test CI-sized
        let reference = run_scenario(&sc, 120, &MonteCarlo::new(SEED).with_threads(1));
        assert_eq!(reference.rounds.len(), sc.rounds);
        for threads in THREAD_COUNTS {
            let got = run_scenario(&sc, 120, &MonteCarlo::new(SEED).with_threads(threads));
            assert_eq!(got, reference, "{name} threads={threads}");
        }
        let csv1 = cogc::figures::scenario_sweep(&sc, 60, 42, 1).to_csv();
        for threads in [2usize, 8] {
            let csvn = cogc::figures::scenario_sweep(&sc, 60, 42, threads).to_csv();
            assert_eq!(csv1, csvn, "{name} CSV threads={threads}");
        }
    }
}

/// Adversarial sweeps obey the same contract: the malicious set and every
/// corruption decision live on per-trial substreams, so the corruption /
/// detection / excision tallies — and the extended CSV — are bit-identical
/// at 1/2/8 threads. Covers bursty and memoryless channels, the no-detect
/// baseline, and (via a retargeted smoke scenario) the sparse FR family.
#[test]
fn adversarial_sweeps_are_bit_identical_across_thread_counts() {
    let mut cases: Vec<scenario::Scenario> =
        ["byz-flip-bursty", "byz-replace", "byz-nodetect", "byz-smoke"]
            .iter()
            .map(|name| scenario::find(name).unwrap())
            .collect();
    // FR-family variant: the group-scan decode path under attack
    // (M=8 is divisible by s+1=4, the sparse family's constraint)
    let mut fr = scenario::find("byz-smoke").unwrap();
    fr.name = "byz-smoke-fr".into();
    fr.code = cogc::gc::CodeFamily::FractionalRepetition;
    match &mut fr.net {
        scenario::NetworkSpec::Homogeneous { m, .. } => *m = 8,
        scenario::NetworkSpec::Perfect { m } => *m = 8,
    }
    fr.validate().unwrap();
    cases.push(fr);

    for sc in &mut cases {
        sc.rounds = 8; // keep the test CI-sized
        let name = sc.name.as_str();
        let reference = run_scenario(sc, 100, &MonteCarlo::new(SEED).with_threads(1));
        assert_eq!(reference.rounds.len(), sc.rounds);
        assert!(
            reference.rounds.iter().any(|r| r.corrupted > 0),
            "{name}: adversary never reached the PS — assertions below are vacuous"
        );
        for threads in THREAD_COUNTS {
            let got = run_scenario(sc, 100, &MonteCarlo::new(SEED).with_threads(threads));
            assert_eq!(got, reference, "{name} threads={threads}");
        }
        let csv1 = cogc::figures::scenario_sweep(sc, 60, 42, 1).to_csv();
        for threads in [2usize, 8] {
            let csvn = cogc::figures::scenario_sweep(sc, 60, 42, threads).to_csv();
            assert_eq!(csv1, csvn, "{name} CSV threads={threads}");
        }
    }
}
