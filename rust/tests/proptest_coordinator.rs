//! Property tests on coordinator-level invariants (coding layer, no PJRT):
//! routing (who hears whom), batching of attempts, decode-state consistency,
//! transmission accounting, and the unbiasedness symmetry of Lemma 5.

use cogc::gc::{self, GcCode};
use cogc::network::{Network, Realization};
use cogc::outage::mc::{gcplus_recovery, RecoveryMode};
use cogc::parallel::MonteCarlo;
use cogc::scenario::Iid;
use cogc::sim::{simulate_round, Decoder, Outcome};
use cogc::testing::Prop;
use cogc::util::rng::Rng;

#[test]
fn prop_routing_respects_code_support() {
    // a client's partial sum must only ever mix gradients from its cyclic
    // incoming neighborhood — erasures can remove terms, never add them.
    Prop::new(40).forall("routing", |rng, _| {
        let m = rng.range(4, 12);
        let s = rng.range(1, m);
        let code = GcCode::generate(m, s, rng);
        let net = Network::homogeneous(m, 0.3, rng.uniform(0.0, 0.9));
        let real = Realization::sample(&net, rng);
        let att = gc::Attempt::observe(&code, &real);
        for row in 0..m {
            let supp = GcCode::support(m, s, row);
            for col in 0..m {
                let v = att.perturbed[(row, col)];
                if !supp.contains(&col) {
                    assert_eq!(v, 0.0, "row {row} leaked col {col}");
                }
                if col != row && !real.t[row][col] {
                    assert_eq!(v, 0.0, "erased link {col}->{row} left a coefficient");
                }
                if col == row {
                    assert_eq!(v, code.b[(row, col)], "diagonal must survive");
                }
            }
        }
        // complete rows are exactly the rows whose incoming links all held
        for &r in &att.complete {
            assert!(att.delivered.contains(&r));
            assert!(code.incoming(r).iter().all(|&k| real.t[r][k]));
        }
    });
}

#[test]
fn prop_standard_outcome_is_binary() {
    // the standard decoder yields the exact mean or nothing (Remark 2)
    Prop::new(30).forall("binary outcome", |rng, _| {
        let m = rng.range(4, 11);
        let s = rng.range(1, m);
        let p = rng.uniform(0.0, 0.8);
        let net = Network::homogeneous(m, p, p);
        let r = simulate_round(&net, &mut Iid, m, s, 8, Decoder::Standard { attempts: 2 }, rng);
        match r.outcome {
            Outcome::Standard { .. } => {
                let agg = r.aggregate.unwrap();
                for (a, t) in agg.iter().zip(&r.true_mean) {
                    assert!((a - t).abs() < 1e-6, "standard decode not exact");
                }
            }
            Outcome::None => assert!(r.aggregate.is_none()),
            other => panic!("standard decoder produced {other:?}"),
        }
    });
}

#[test]
fn prop_transmission_accounting() {
    // per attempt: s*M sharing; uplinks = complete count (standard) or M (GC+)
    Prop::new(30).forall("tx accounting", |rng, _| {
        let m = rng.range(4, 11);
        let s = rng.range(1, m);
        let net = Network::homogeneous(m, 0.5, 0.5);
        let tr = rng.range(1, 4);
        let r = simulate_round(&net, &mut Iid, m, s, 4, Decoder::GcPlus { tr }, rng);
        // GC+ sends every partial sum: attempts * (sM + M); it may stop at
        // a standard shortcut, so tx is a multiple of sM + M up to tr
        let per = s * m + m;
        assert!(r.transmissions % per == 0 || r.transmissions <= tr * per);
        assert!(r.transmissions <= tr * per);
        assert!(r.transmissions >= per);
    });
}

#[test]
fn prop_gcplus_subset_means_match_ground_truth() {
    // whatever subset GC+ decodes, the aggregate equals the true subset mean
    Prop::new(25).forall("subset mean", |rng, _| {
        let m = rng.range(5, 11);
        let s = rng.range(2, m);
        let net = Network::homogeneous(m, rng.uniform(0.2, 0.7), rng.uniform(0.2, 0.7));
        let r = simulate_round(&net, &mut Iid, m, s, 6, Decoder::GcPlus { tr: 2 }, rng);
        if let Outcome::Full = r.outcome {
            let agg = r.aggregate.unwrap();
            for (a, t) in agg.iter().zip(&r.true_mean) {
                assert!((a - t).abs() < 1e-6);
            }
        }
        // decode error is checked inside simulate_round for partial subsets
        assert!(r.decode_err < 1e-5, "decode err {}", r.decode_err);
    });
}

#[test]
fn lemma5_symmetry_uniform_inclusion() {
    // Lemma 5's premise: in a homogeneous network every client is equally
    // likely to be decodable — the k4 membership frequencies must be
    // statistically indistinguishable across clients.
    let m = 8;
    let net = Network::homogeneous(m, 0.5, 0.5);
    let mut rng = Rng::new(99);
    let mut counts = vec![0usize; m];
    let trials = 1500;
    for _ in 0..trials {
        let code = GcCode::generate(m, 5, &mut rng);
        let real = Realization::sample(&net, &mut rng);
        let att = gc::Attempt::observe(&code, &real);
        let stacked = gc::stack_attempts(&[att]);
        if stacked.rows == 0 {
            continue;
        }
        for c in gc::decode(&stacked).k4 {
            counts[c] += 1;
        }
    }
    let total: usize = counts.iter().sum();
    if total == 0 {
        panic!("no decodes at all");
    }
    let mean = total as f64 / m as f64;
    for (c, &cnt) in counts.iter().enumerate() {
        // 5-sigma binomial-ish band around the symmetric mean
        let sigma = (mean * (1.0 - 1.0 / m as f64)).sqrt();
        assert!(
            (cnt as f64 - mean).abs() < 5.0 * sigma + 0.05 * mean,
            "client {c} inclusion {cnt} deviates from mean {mean:.1} (counts {counts:?})"
        );
    }
}

#[test]
fn until_decode_always_terminates_with_something() {
    for setting in 1..=4 {
        let net = Network::fig6_setting(setting, 10);
        let st = gcplus_recovery(
            &net,
            &Iid,
            10,
            7,
            RecoveryMode::UntilDecode { tr: 2, max_blocks: 80 },
            150,
            &MonteCarlo::new(5 + setting as u64),
        );
        assert!(
            st.p_none() < 0.05,
            "setting {setting}: Algorithm 1 failed to decode {:.3}",
            st.p_none()
        );
    }
}
