//! Scenario-engine regression tests: the degenerate-equivalence guard
//! (stateful channel models configured to be memoryless must reproduce the
//! i.i.d. figures byte-for-byte), registry/JSON integrity, and end-to-end
//! coverage of every channel model through the sim and outage estimators.

use cogc::network::Network;
use cogc::outage::mc::{estimate_outage, gcplus_recovery, RecoveryMode};
use cogc::parallel::MonteCarlo;
use cogc::scenario::{
    run_scenario, ChannelSpec, CorrelatedFading, DeadlineStraggler, GilbertElliott, Iid, Scenario,
};
use cogc::sim::{self, Decoder};
use cogc::util::rng::Rng;

/// Degenerate-equivalence guard, figure level: a Gilbert–Elliott channel
/// with equal good/bad outage probabilities (scale 1 in both states) must
/// produce the *byte-identical* fig4 and fig6 CSVs of the i.i.d. channel —
/// burst-state bookkeeping may never leak into the emission stream.
#[test]
fn degenerate_gilbert_elliott_reproduces_iid_fig4_fig6_csvs() {
    let ge = GilbertElliott::new(0.2, 0.3, (1.0, 1.0), (1.0, 1.0));

    let fig4_iid = cogc::figures::fig4_channel(&Iid, 400, 42, 2).to_csv();
    let fig4_ge = cogc::figures::fig4_channel(&ge, 400, 42, 2).to_csv();
    assert_eq!(fig4_iid, fig4_ge, "fig4 CSV must be byte-identical");

    let fig6_iid = cogc::figures::fig6_channel(&Iid, 100, 42, 2).to_csv();
    let fig6_ge = cogc::figures::fig6_channel(&ge, 100, 42, 2).to_csv();
    assert_eq!(fig6_iid, fig6_ge, "fig6 CSV must be byte-identical");
}

/// Degenerate-equivalence guard, estimator level: a deadline-straggler
/// channel with deadline = ∞ matches the i.i.d. tallies bit-for-bit (the
/// latency draws live on the private stream and every one of them beats an
/// infinite deadline).
#[test]
fn infinite_deadline_straggler_matches_iid_tallies() {
    let net = Network::fig6_setting(2, 10);
    let code = cogc::gc::GcCode::generate(10, 7, &mut Rng::new(1));
    let ds = DeadlineStraggler::new(f64::INFINITY, 0.5, 1.0, 0.2, 0.2, 3.0);

    let mc = MonteCarlo::new(0xD00D);
    let po_iid = estimate_outage(&net, &code, &Iid, 3_000, &mc);
    let po_ds = estimate_outage(&net, &code, &ds, 3_000, &mc);
    assert_eq!(po_iid.to_bits(), po_ds.to_bits(), "outage estimate must match bit-exactly");

    let rec_iid =
        gcplus_recovery(&net, &Iid, 10, 7, RecoveryMode::FixedTr(2), 800, &MonteCarlo::new(5));
    let rec_ds =
        gcplus_recovery(&net, &ds, 10, 7, RecoveryMode::FixedTr(2), 800, &MonteCarlo::new(5));
    assert_eq!(rec_iid, rec_ds, "recovery stats (incl. |K4| histogram) must match");

    let sweep_iid =
        sim::sweep(&net, &Iid, 10, 7, 5, Decoder::GcPlus { tr: 2 }, 300, &MonteCarlo::new(9));
    let sweep_ds =
        sim::sweep(&net, &ds, 10, 7, 5, Decoder::GcPlus { tr: 2 }, 300, &MonteCarlo::new(9));
    assert_eq!(sweep_iid, sweep_ds, "sim sweep stats must match");
}

/// Zero-coupling correlated fading is the third degenerate case.
#[test]
fn zero_coupling_fading_matches_iid_tallies() {
    let net = Network::homogeneous(8, 0.3, 0.3);
    let code = cogc::gc::GcCode::generate(8, 5, &mut Rng::new(2));
    let cf = CorrelatedFading::new(0.0, 25.0, 0.9);
    let mc = MonteCarlo::new(77);
    let a = estimate_outage(&net, &code, &Iid, 2_000, &mc);
    let b = estimate_outage(&net, &code, &cf, 2_000, &mc);
    assert_eq!(a.to_bits(), b.to_bits());
}

/// Non-degenerate stateful channels must actually change the statistics —
/// otherwise the engine is dead code. Per-link chains at stationarity leave
/// *single-attempt* statistics untouched (links are independent with the
/// same marginal), so the burstiness is visible exactly where the paper's
/// repetition protocols live: across stacked attempts. A c2c link that is
/// alternately perfect/dead with high persistence has the same marginal
/// outage 0.5 as the i.i.d. channel, but its two stacked attempts are
/// nearly copies of each other — the GC⁺ recovery split must shift.
#[test]
fn bursty_channel_changes_multi_attempt_statistics() {
    // every link alternates perfect/dead with persistence 0.8 (pb = 0.5,
    // outage 0 in the good state, 1 in the bad — 0.5·2 clamped), so the
    // stationary marginal equals the network's iid p = 0.5 on both sides
    let net = Network::homogeneous(10, 0.5, 0.5);
    let ge = GilbertElliott::new(0.1, 0.1, (0.0, 2.0), (0.0, 2.0));
    assert!((ge.stationary_outage_c2c(0.5) - 0.5).abs() < 1e-12);
    assert!((ge.stationary_outage_c2s(0.5) - 0.5).abs() < 1e-12);

    // single attempt: identical statistics (independent links, same
    // marginal) — the MC estimates must agree within noise
    let net2 = Network::homogeneous(10, 0.4, 0.25);
    let ge2 = GilbertElliott::new(0.05, 0.15, (0.0, 4.0), (1.0, 1.0));
    assert!((ge2.stationary_outage_c2c(0.25) - 0.25).abs() < 1e-12);
    let code = cogc::gc::GcCode::generate(10, 7, &mut Rng::new(3));
    let trials = 20_000;
    let po_iid = estimate_outage(&net2, &code, &Iid, trials, &MonteCarlo::new(4));
    let po_ge = estimate_outage(&net2, &code, &ge2, trials, &MonteCarlo::new(4));
    let sigma = (po_iid.max(1e-3) * (1.0 - po_iid.max(1e-3)) / trials as f64).sqrt();
    assert!(
        (po_iid - po_ge).abs() < 6.0 * sigma + 5e-3,
        "single-attempt P_O must be marginal-equal: iid {po_iid:.4} vs ge {po_ge:.4}"
    );

    // two stacked attempts: the temporal correlation must move the split
    // (numpy mirror of this exact config measures TV ≈ 0.11)
    let rec_trials = 10_000;
    let mode = RecoveryMode::FixedTr(2);
    let rec_iid = gcplus_recovery(&net, &Iid, 10, 7, mode, rec_trials, &MonteCarlo::new(6));
    let rec_ge = gcplus_recovery(&net, &ge, 10, 7, mode, rec_trials, &MonteCarlo::new(6));
    // total-variation distance over the 4-way outcome split
    let n = rec_trials as f64;
    let split = |r: &cogc::outage::RecoveryStats| {
        [r.standard as f64 / n, r.full as f64 / n, r.partial as f64 / n, r.none as f64 / n]
    };
    let (a, b) = (split(&rec_iid), split(&rec_ge));
    let tv = 0.5 * a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum::<f64>();
    assert!(
        tv > 0.03,
        "bursty dynamics left the 2-attempt recovery split unchanged (TV = {tv:.4}): \
         iid full/partial/none = {:.3}/{:.3}/{:.3}, ge = {:.3}/{:.3}/{:.3}",
        rec_iid.p_full(),
        rec_iid.p_partial(),
        rec_iid.p_none(),
        rec_ge.p_full(),
        rec_ge.p_partial(),
        rec_ge.p_none()
    );
}

/// Every built-in scenario runs end-to-end through the figure harness and
/// emits a well-formed time series.
#[test]
fn every_builtin_scenario_emits_a_well_formed_time_series() {
    for sc in cogc::scenario::builtin() {
        let t = cogc::figures::scenario_sweep(&sc, 10, 7, 0);
        assert_eq!(t.rows.len(), sc.rounds, "{}", sc.name);
        assert_eq!(t.header.len(), 10, "{}", sc.name);
        let csv = t.to_csv();
        assert!(csv.contains(&sc.name), "comment must name the scenario");
        for row in &t.rows {
            // p_standard + p_full + p_partial + p_none == 1 (columns 3..=6)
            let sum: f64 = row[3..=6].iter().map(|c| c.parse::<f64>().unwrap()).sum();
            assert!((sum - 1.0).abs() < 1e-9, "{}: outcome split sums to {sum}", sc.name);
            let hit: f64 = row[9].parse().unwrap();
            assert!((0.0..=1.0).contains(&hit), "{}", sc.name);
        }
    }
}

/// A scenario spec written to disk loads back and runs (the
/// `cogc scenario run --file` path).
#[test]
fn scenario_json_file_roundtrip_and_run() {
    let sc = cogc::scenario::find("straggler-harsh").unwrap();
    let path = std::env::temp_dir().join("cogc_scenario_roundtrip.json");
    std::fs::write(&path, sc.to_json().serialize()).unwrap();
    let loaded = Scenario::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded, sc);
    let series = run_scenario(&loaded, 5, &MonteCarlo::new(1));
    assert_eq!(series.rounds.len(), loaded.rounds);
    // harsh deadlines must be visible in the diagnostics
    let misses: usize = series
        .rounds
        .iter()
        .map(|t| t.channel.deadline_total - t.channel.deadline_hits)
        .sum();
    assert!(misses > 0, "straggler-harsh should miss deadlines");
}

/// The trainer accepts a stateful channel spec and stays seed-reproducible
/// (two identical runs, same CSV), exercising channel state across rounds
/// inside the full training loop.
#[test]
fn trainer_with_bursty_channel_is_reproducible() {
    use cogc::coordinator::{Aggregator, TrainConfig, Trainer};
    let backend = cogc::runtime::Backend::native();
    let m = backend.manifest().m;
    let net = Network::homogeneous(m, 0.4, 0.2);
    let mk_cfg = || {
        let mut cfg = TrainConfig::new(
            "mnist_cnn",
            Aggregator::GcPlus { tr: 2, until_decode: true, max_blocks: 10 },
        );
        cfg.rounds = 3;
        cfg.per_client = 40;
        cfg.eval_batches = 2;
        cfg.seed = 11;
        cfg.combine = cogc::runtime::CombineImpl::Native;
        cfg.channel = ChannelSpec::GilbertElliott {
            p_gb: 0.1,
            p_bg: 0.2,
            c2c_scale: (0.5, 4.0),
            c2s_scale: (0.5, 4.0),
        };
        cfg
    };
    let log_a = Trainer::new(&backend, mk_cfg(), net.clone()).unwrap().run().unwrap();
    let log_b = Trainer::new(&backend, mk_cfg(), net).unwrap().run().unwrap();
    assert_eq!(log_a.to_csv(), log_b.to_csv(), "bursty training must be seed-reproducible");
    assert_eq!(log_a.rounds.len(), 3);
}
