//! Determinism contract of the telemetry layer (ISSUE 9 satellite).
//!
//! Three guarantees, asserted end-to-end through the public CLI-facing
//! entry points:
//!
//! 1. **Disarmed output is untouched**: figure CSVs are byte-identical
//!    whether or not the registry is armed, and clean (disarmed) sweep
//!    CSVs never grow the armed-only columns.
//! 2. **Armed sweeps only append**: the armed per-round CSV equals the
//!    clean CSV plus exactly two trailing columns per row.
//! 3. **Merged counters are engine-deterministic**: the registry snapshot
//!    after an armed run is bit-identical at `--threads` 1/2/8, and the
//!    `deterministic` JSON subtree is byte-stable — wall-clock only ever
//!    appears under `non_deterministic`.
//!
//! The registry is process-global and cargo runs test fns on parallel
//! threads, so every test takes the file-local `LOCK`.

use std::sync::Mutex;

use cogc::figures;
use cogc::parallel::MonteCarlo;
use cogc::scenario::{self, run_scenario};
use cogc::telemetry::{self, metric};
use cogc::util::json::Json;

static LOCK: Mutex<()> = Mutex::new(());

/// Run `f` with the registry freshly armed; return its output plus the
/// merged deterministic snapshot and the JSON export taken at the end.
fn armed_run<T>(f: impl FnOnce() -> T) -> (T, telemetry::Shard, String) {
    telemetry::reset();
    telemetry::arm();
    let out = f();
    telemetry::disarm();
    let snap = telemetry::snapshot();
    let json = telemetry::export_json().serialize();
    telemetry::reset();
    (out, snap, json)
}

#[test]
fn armed_fig4_csv_is_byte_identical_to_disarmed() {
    let _g = LOCK.lock().unwrap();
    telemetry::reset();
    telemetry::disarm();
    let clean = figures::fig4(300, 7, 2).to_csv();
    let (armed, snap, _) = armed_run(|| figures::fig4(300, 7, 2).to_csv());
    assert_eq!(clean, armed, "arming telemetry must not perturb figure CSVs");
    assert!(snap.counter(metric::MC_TRIALS) > 0, "the armed run must have counted trials");
}

#[test]
fn armed_sweep_csv_equals_clean_csv_plus_two_columns() {
    let _g = LOCK.lock().unwrap();
    let sc = scenario::find("smoke").unwrap();
    telemetry::reset();
    telemetry::disarm();
    let clean = figures::scenario_sweep(&sc, 50, 7, 2).to_csv();
    assert!(
        !clean.contains("mean_peeled"),
        "clean sweep CSVs must stay byte-identical to the pre-telemetry format"
    );
    let (armed, snap, _) = armed_run(|| figures::scenario_sweep(&sc, 50, 7, 2).to_csv());
    assert!(armed.contains("mean_peeled,mean_forwarded"));
    // dropping the two trailing fields of every non-comment line must
    // reproduce the clean CSV byte-for-byte
    let mut stripped = String::new();
    for line in armed.lines() {
        if line.starts_with('#') {
            stripped.push_str(line);
        } else {
            let fields: Vec<&str> = line.split(',').collect();
            assert!(fields.len() > 2, "armed row too short: {line:?}");
            stripped.push_str(&fields[..fields.len() - 2].join(","));
        }
        stripped.push('\n');
    }
    assert_eq!(stripped, clean, "armed sweep CSV must be clean CSV + appended columns");
    // the decode pipeline counters behind the columns must have moved
    assert!(snap.counter(metric::DEC_ROWS_PUSHED) > 0);
    assert_eq!(
        snap.counter(metric::DEC_ROWS_PEELED) + snap.counter(metric::DEC_ROWS_FORWARDED),
        snap.counter(metric::DEC_ROWS_PUSHED),
        "peel/forward split must partition the pushed rows"
    );
}

#[test]
fn armed_registry_and_tallies_are_thread_invariant() {
    let _g = LOCK.lock().unwrap();
    for name in ["smoke", "byz-smoke"] {
        let sc = scenario::find(name).unwrap();
        // chunk 4 forces real multi-worker fan-out (24 trials = 6 chunks);
        // the default chunk of 256 would collapse these runs to one worker
        let run = |threads: usize| {
            armed_run(|| {
                run_scenario(&sc, 24, &MonteCarlo::new(17).with_threads(threads).with_chunk(4))
            })
        };
        let (want_series, want_snap, want_json) = run(1);
        assert_eq!(want_snap.counter(metric::MC_TRIALS), 24, "{name}");
        let want_det = deterministic_subtree(&want_json);
        for threads in [2usize, 8] {
            let (series, snap, json) = run(threads);
            assert_eq!(series, want_series, "{name} tallies at threads={threads}");
            assert_eq!(snap, want_snap, "{name} registry at threads={threads}");
            assert_eq!(
                deterministic_subtree(&json),
                want_det,
                "{name} deterministic JSON subtree at threads={threads}"
            );
        }
        if name == "byz-smoke" {
            assert!(
                want_snap.counter(metric::AUDIT_CHECKS) > 0,
                "adversarial sweeps must count audit checks"
            );
        }
    }
}

/// Serialize only the `deterministic` key of a telemetry export.
fn deterministic_subtree(json: &str) -> String {
    let v = Json::parse(json).expect("telemetry export must parse");
    v.get("deterministic").expect("export must carry a deterministic section").serialize()
}

#[test]
fn export_satisfies_checker_and_confines_wall_clock() {
    let _g = LOCK.lock().unwrap();
    let sc = scenario::find("smoke").unwrap();
    let (_, _, json) = armed_run(|| {
        run_scenario(&sc, 12, &MonteCarlo::new(5).with_threads(2).with_chunk(4))
    });
    let msg = telemetry::check_json(&json).expect("export must satisfy its own checker");
    assert!(msg.contains("telemetry ok"), "{msg}");
    let v = Json::parse(&json).unwrap();
    // wall-clock lives only under non_deterministic: worker stats recorded
    // by the armed engine are there, and the deterministic subtree holds
    // nothing but integer counters/gauges/histograms
    let workers = v
        .get("non_deterministic")
        .and_then(|nd| nd.get("workers"))
        .and_then(Json::as_arr)
        .expect("armed engine runs must record worker throughput");
    assert!(!workers.is_empty());
    let det = v.get("deterministic").unwrap().serialize();
    assert!(!det.contains("elapsed"), "wall-clock leaked into the deterministic section");
    // the Prometheus seam renders the same counters
    telemetry::reset();
    telemetry::arm();
    let _ = run_scenario(&sc, 4, &MonteCarlo::new(5).with_threads(1));
    telemetry::disarm();
    let prom = telemetry::render_prometheus();
    assert!(prom.contains("# TYPE cogc_mc_trials counter"), "{prom}");
    assert!(prom.contains("cogc_dec_rank_bucket"), "{prom}");
    telemetry::reset();
}

#[test]
fn disarmed_runs_record_no_phases_or_workers() {
    let _g = LOCK.lock().unwrap();
    telemetry::reset();
    telemetry::disarm();
    let sc = scenario::find("smoke").unwrap();
    let _ = run_scenario(&sc, 8, &MonteCarlo::new(3).with_threads(2));
    let v = Json::parse(&telemetry::export_json().serialize()).unwrap();
    let nd = v.get("non_deterministic").unwrap();
    assert!(nd.get("workers").and_then(Json::as_arr).unwrap().is_empty());
    assert!(nd.get("phases").and_then(Json::as_obj).unwrap().is_empty());
    // deterministic counters still merged (they cost integer bumps only
    // and keep disarmed/armed values identical by construction)
    let snap = telemetry::snapshot();
    assert_eq!(snap.counter(metric::MC_TRIALS), 8);
    telemetry::reset();
}
