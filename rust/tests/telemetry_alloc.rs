//! Allocation contract of the telemetry hot path (ISSUE 9 acceptance
//! criterion): disabled telemetry adds **zero** allocations to trial
//! bodies, and the shard plumbing itself never allocates per trial.
//!
//! Counts allocator *calls* under a counting `#[global_allocator]` (the
//! same pattern as the peak-tracking allocator of `tests/fr_large_m.rs`):
//! per-trial regressions show up as a count that scales with the trial
//! count, which the doubling assertion below catches exactly. Everything
//! runs inside ONE test fn — a second concurrently-running test thread
//! would bleed its allocations into the global counter and turn the
//! exact-zero asserts flaky.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use cogc::parallel::MonteCarlo;
use cogc::telemetry::{self, metric};

struct CountAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, p: *mut u8, layout: Layout) {
        System.dealloc(p, layout);
    }
}

#[global_allocator]
static ALLOC: CountAlloc = CountAlloc;

fn shard_of(s: &mut telemetry::Shard) -> Option<&mut telemetry::Shard> {
    Some(s)
}

/// Allocator calls of a serial `run_scratch_tel` sweep with an
/// instrumented trial body, telemetry disarmed.
fn sweep_allocs(trials: usize) -> usize {
    let mc = MonteCarlo::new(11).with_threads(1).with_chunk(64);
    let before = ALLOCS.load(Ordering::Relaxed);
    let total: usize = mc.run_scratch_tel(
        trials,
        telemetry::Shard::default,
        shard_of,
        |_t, rng, acc: &mut usize, sh| {
            sh.inc(metric::DEC_EPISODES);
            sh.observe(metric::H_DEC_ROWS, rng.range(0, 64) as u64);
            *acc += 1;
        },
    );
    assert_eq!(total, trials);
    ALLOCS.load(Ordering::Relaxed) - before
}

#[test]
fn disabled_telemetry_hot_path_allocates_nothing_per_trial() {
    telemetry::disarm();
    telemetry::reset();

    // The raw shard primitives and the disarmed phase guard are pure
    // integer work: exactly zero allocator calls across 10⁴ iterations.
    let mut sh = telemetry::Shard::new();
    let before = ALLOCS.load(Ordering::Relaxed);
    for i in 0..10_000u64 {
        sh.inc(metric::DEC_EPISODES);
        sh.add(metric::DEC_ROWS_PUSHED, i & 7);
        sh.observe(metric::H_DEC_RANK, i);
        sh.gauge_max(metric::DEC_MAX_RANK, i);
        let _p = telemetry::phase("alloc-probe"); // disarmed: no clock read
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(after - before, 0, "telemetry primitives must not touch the allocator");
    assert_eq!(sh.counter(metric::DEC_EPISODES), 10_000);

    // Doubling the trial count of a serial engine sweep must not change
    // the allocator-call count: every allocation is per-run (pool setup),
    // none is per-trial or per-chunk. A single leaked per-trial
    // allocation fails the assert by ≥ 2000.
    let _warm = sweep_allocs(2_000); // registry/pool warm-up
    let base = sweep_allocs(2_000);
    let doubled = sweep_allocs(4_000);
    assert_eq!(
        base, doubled,
        "allocator calls scale with trials: the telemetry hot path allocates per trial"
    );
    telemetry::reset();
}
