//! Offline stub of the XLA/PJRT bindings.
//!
//! The `cogc` runtime layer (`runtime::engine` and friends) compiles against
//! the API surface of the real `xla` bindings: a PJRT CPU client that loads
//! HLO-text artifacts produced by `make artifacts` and executes them. Those
//! bindings link a large native `xla_extension` library that cannot be
//! fetched or built in an offline checkout, so this crate provides the same
//! API shape with every execution entry point failing fast at runtime.
//!
//! Behaviour:
//! - [`PjRtClient::cpu`] returns an error, so `Engine::cpu()` (and with it
//!   every artifact-dependent code path: training, figs. 7–12, `cogc info`)
//!   reports "PJRT backend unavailable" instead of failing to build.
//! - [`Literal`] construction helpers succeed (they are pure host-side
//!   bookkeeping) so value-building code is exercised; extraction helpers
//!   error because nothing can have been executed.
//!
//! The pure-rust paths (coding theory, outage analysis, the Monte-Carlo
//! engine, synthetic simulation) never touch this crate at runtime.

use std::fmt;

/// Error type mirroring the real bindings' error enum closely enough for
/// `anyhow` interop (`std::error::Error + Send + Sync + 'static`).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT backend unavailable: this build uses the vendored no-op `xla` stub \
         (rust/vendor/xla). Artifact execution requires the real XLA/PJRT bindings \
         and the AOT artifacts from `make artifacts`."
            .to_string(),
    )
}

/// Host-side literal handle. The stub keeps no data: literals only ever flow
/// into [`PjRtLoadedExecutable::execute`], which cannot succeed here.
#[derive(Debug, Clone, Default)]
pub struct Literal {
    _priv: (),
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal::default()
    }

    /// Build a scalar literal.
    pub fn scalar<T>(_x: T) -> Literal {
        Literal::default()
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal::default())
    }

    /// Decompose a tuple literal. Nothing can have produced a real tuple.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    /// Extract the flat host data. Nothing can have produced real data.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    /// Extract the first element.
    pub fn get_first_element<T>(&self) -> Result<T> {
        Err(unavailable())
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with positional inputs (`T` is `Literal` or `&Literal`).
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] is the single entry point the
/// coordinator uses; it fails fast in the stub.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must not yield a client");
        assert!(err.to_string().contains("PJRT backend unavailable"));
    }

    #[test]
    fn literal_construction_succeeds_extraction_fails() {
        let lit = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        assert!(Literal::scalar(3u32).get_first_element::<u32>().is_err());
        assert!(Literal::default().to_tuple().is_err());
    }
}
